"""Multi-tenant read service (ISSUE 7): coalesced results must stay
byte-identical to independent ``Dataset.read`` calls under every engine;
the vectorized request-merge must match a naive reference merger
bit-for-bit; generation-keyed plan caches must drop on a concurrent
reorganization commit (zero torn reads while racing one); and per-tenant
telemetry must aggregate — never last-tenant-wins — into the layout
policy's history."""

import threading
import time

import numpy as np
import pytest

from repro.core import plan_layout, uniform_grid_blocks
from repro.core.blocks import Block
from repro.core.policy import LayoutPolicy
from repro.io import Dataset, ENGINES, reorganize
from repro.serve.coalesce import (Request, build_super_plan, union_spans,
                                  union_spans_naive)
from repro.serve.read_service import ReadService

GLOBAL = (48, 48)
BLOCK = (8, 8)


def _build(dirpath, engine="pread", var="T"):
    rng = np.random.default_rng(7)
    blocks = uniform_grid_blocks(GLOBAL, BLOCK)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    plan = plan_layout("chunked", blocks, num_procs=4, global_shape=GLOBAL)
    ds = Dataset.create(dirpath, engine=engine)
    ds.write_planned(ds.plan_write(var, plan, np.float32), data)
    ds.close()
    return ref


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("svc") / "data")
    ref = _build(d)
    return d, ref


# -- vectorized merge vs naive reference (property sweep) --------------------

def test_union_spans_matches_naive_reference():
    """Seeded random sweep: the vectorized interval union must be
    bit-identical to the one-span-at-a-time reference on overlapping,
    nested, adjacent, duplicate and multi-subfile inputs."""
    rng = np.random.default_rng(42)
    for trial in range(300):
        n = int(rng.integers(0, 40))
        subf = rng.integers(0, 4, size=n)
        lo = rng.integers(0, 256, size=n)
        hi = lo + rng.integers(1, 64, size=n)
        got = union_spans(subf, lo, hi)
        want = union_spans_naive(subf, lo, hi)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        us, ul, uh = got
        # structural invariants: sorted, disjoint with real gaps, covering
        for k in range(1, len(ul)):
            assert (us[k], ul[k]) > (us[k - 1], ul[k - 1])
            if us[k] == us[k - 1]:
                assert ul[k] > uh[k - 1]
        for s, l, h in zip(subf, lo, hi):
            m = (us == s) & (ul <= l) & (uh >= h)
            assert m.any(), "input span not covered by the union"


def test_union_spans_adjacency_and_boundaries():
    # byte-adjacent spans merge ...
    s, l, h = union_spans([0, 0], [0, 10], [10, 20])
    assert len(l) == 1 and l[0] == 0 and h[0] == 20
    # ... a one-byte gap does not ...
    s, l, h = union_spans([0, 0], [0, 11], [10, 20])
    assert len(l) == 2
    # ... and subfile boundaries never merge, even at extreme offsets
    s, l, h = union_spans([0, 1], [0, 0], [100, 100])
    assert len(l) == 2 and list(s) == [0, 1]
    s, l, h = union_spans([], [], [])
    assert len(s) == 0


# -- byte identity with independent reads, all engines -----------------------

REGION_SETS = {
    "overlapping": [Block((0, 0), (24, 48)), Block((12, 0), (36, 48)),
                    Block((20, 8), (48, 40))],
    "disjoint": [Block((0, 0), (16, 48)), Block((24, 0), (40, 48)),
                 Block((40, 0), (48, 24))],
    "adjacent": [Block((0, 0), (16, 48)), Block((16, 0), (32, 48)),
                 Block((32, 0), (48, 48))],
}


@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("kind", sorted(REGION_SETS))
def test_coalesced_identical_to_independent(world, engine, kind):
    d, ref = world
    regions = REGION_SETS[kind]
    checker = Dataset.open(d, engine=engine, telemetry=False)
    ds = Dataset.open(d, engine=engine)
    with ReadService(ds, window_s=0.02) as svc:
        futs = [svc.submit(f"tenant{i}", "T", r)
                for i, r in enumerate(regions)]
        for r, f in zip(regions, futs):
            arr, st = f.result(timeout=30)
            want, _ = checker.read("T", r)
            np.testing.assert_array_equal(arr, want)
            np.testing.assert_array_equal(arr, ref[r.slices()])
            assert st.bytes_read == want.nbytes
    ds.close()
    checker.close()


def test_batch_front_door_order_and_identity(world):
    d, ref = world
    ds = Dataset.open(d, engine="pread")
    reqs = [Request("a", "T", REGION_SETS["overlapping"][0]),
            Request("b", "T", REGION_SETS["overlapping"][1]),
            Request("a", "T", REGION_SETS["disjoint"][2])]
    with ReadService(ds, window_s=0.5) as svc:   # long window: flush beats it
        t0 = time.perf_counter()
        results = svc.read_batch(reqs)
        assert time.perf_counter() - t0 < 0.5    # batch didn't wait the window
    for req, (arr, _) in zip(reqs, results):
        np.testing.assert_array_equal(arr, ref[req.region.slices()])
    ds.close()


# -- one probe, one gather, plan cache ---------------------------------------

def test_super_plan_one_gather_and_cache_hits(world):
    d, ref = world
    ds = Dataset.open(d, engine="pread")
    regions = REGION_SETS["overlapping"]
    reqs = [Request(f"t{i}", "T", r) for i, r in enumerate(regions)]
    with ReadService(ds, window_s=0.0) as svc:
        svc.read_batch(reqs)
        assert svc.stats.super_plans == 1        # one shared gather
        assert svc.stats.cache_misses == 1 and svc.stats.cache_hits == 0
        # overlap folds: the shared gather moves fewer bytes than the
        # members' payloads sum to
        assert svc.stats.fetch_bytes < svc.stats.bytes_served
        svc.read_batch(reqs)
        assert svc.stats.cache_hits == 1         # same batch -> cached plan
        assert svc.stats.super_plans == 2
    ds.close()


def test_super_plan_construction_shape(world):
    d, _ = world
    ds = Dataset.open(d, telemetry=False)
    sp = build_super_plan(ds.index, "T", REGION_SETS["overlapping"])
    assert sp.num_members == 3
    assert sp.payload_bytes == sum(p.bytes_needed for p in sp.members)
    assert sp.fetch_bytes <= sp.payload_bytes    # overlap deduplicated
    fetch = sp.fetch_plan()
    assert fetch.bytes_needed == sp.fetch_bytes
    assert fetch.num_groups == sp.num_spans      # one transfer per span
    # every member row maps to the span that contains its bytes
    for plan, span_of in zip(sp.members, sp.member_span):
        for row in range(plan.num_chunks):
            k = int(span_of[row])
            assert sp.span_subfiles[k] == plan.subfiles[row]
            assert sp.span_lo[k] <= plan.file_lo[row]
            assert sp.span_hi[k] >= plan.file_hi[row]
    # a region intersecting nothing still plans (empty member)
    sp = build_super_plan(ds.index, "T", [Block((0, 0), (1, 1)),
                                          Block((47, 47), (48, 48))])
    assert sp.num_members == 2 and sp.fetch_bytes > 0
    ds.close()


# -- window, admission control, fairness -------------------------------------

def test_window_coalesces_concurrent_submits(world):
    d, ref = world
    ds = Dataset.open(d, engine="pread")
    with ReadService(ds, window_s=0.25) as svc:
        futs = [svc.submit(f"t{i % 3}", "T", REGION_SETS["overlapping"][i % 3])
                for i in range(6)]
        for f in futs:
            f.result(timeout=30)
        assert svc.stats.batches == 1            # all six landed in one window
        assert svc.stats.requests == 6
        assert svc.tenant_stats("t0").coalesced == 2
    ds.close()


def test_admission_control_bounds_batch_bytes(world):
    """Admission charges the UNION of the stored spans a batch would
    fetch, not the sum of logical payloads: disjoint regions whose spans
    together exceed the limit split across batches (at least one request
    always enters), while identical regions — fetched once by the shared
    gather — coalesce into a single admitted batch."""
    d, ref = world
    ds = Dataset.open(d, engine="pread")
    # four disjoint slabs, 3072 stored bytes each: the limit fits one
    regions = [Block((i * 16, 0), ((i + 1) * 16, 48)) for i in range(3)]
    regions.append(Block((0, 0), (16, 48)))      # duplicate of slab 0
    with ReadService(ds, window_s=0.01,
                     max_inflight_bytes=4000) as svc:  # < 2 disjoint slabs
        futs = [svc.submit("t", "T", r) for r in regions[:3]]
        for f, r in zip(futs, regions[:3]):
            arr, _ = f.result(timeout=30)
            np.testing.assert_array_equal(arr, ref[r.slices()])
        assert svc.stats.batches >= 3            # one disjoint slab each
        assert svc.stats.deferred > 0
    ds.close()
    # overlapping requests are fetched once, so they are charged once:
    # five copies of one 3072-byte slab union to 3072 < 4000 and admit
    # as ONE batch under the very limit that split the disjoint slabs
    ds = Dataset.open(d, engine="pread")
    with ReadService(ds, window_s=0.05,
                     max_inflight_bytes=4000) as svc:
        futs = [svc.submit("t", "T", regions[0]) for _ in range(5)]
        for f in futs:
            arr, _ = f.result(timeout=30)
            np.testing.assert_array_equal(arr, ref[regions[0].slices()])
        assert svc.stats.batches == 1
        assert svc.stats.fetch_bytes == 3072
        assert svc.stats.deferred == 0
    ds.close()


def test_round_robin_fairness_across_tenants(world):
    """A tenant with one queued request lands in the first batch even when
    another tenant queued many ahead of it."""
    d, _ = world
    region = Block((0, 0), (8, 48))
    ds = Dataset.open(d, engine="pread")
    order, lock = [], threading.Lock()

    def tag(name):
        def cb(_fut):
            with lock:
                order.append(name)
        return cb

    with ReadService(ds, window_s=0.25, max_batch=2) as svc:
        for i in range(6):
            svc.submit("chatty", "T", region).add_done_callback(tag("chatty"))
        fb = svc.submit("quiet", "T", region)
        fb.add_done_callback(tag("quiet"))
        fb.result(timeout=30)
        assert svc.stats.batches >= 1
    assert "quiet" in order[:2], f"quiet tenant starved: {order}"
    ds.close()


def test_closed_service_rejects_and_drains(world):
    d, ref = world
    region = Block((0, 0), (8, 48))
    ds = Dataset.open(d, engine="pread")
    svc = ReadService(ds, window_s=5.0)          # window close() must beat
    fut = svc.submit("t", "T", region)
    svc.close()
    arr, _ = fut.result(timeout=5)               # drained, not dropped
    np.testing.assert_array_equal(arr, ref[region.slices()])
    with pytest.raises(RuntimeError):
        svc.submit("t", "T", region)
    svc.close()                                  # idempotent
    ds.close()


# -- generation invalidation + racing reorganization -------------------------

def _reorg_layout(scheme):
    blocks = uniform_grid_blocks(GLOBAL, BLOCK)
    return plan_layout("reorganized", blocks, num_procs=4,
                       global_shape=GLOBAL, reorg_scheme=scheme)


def test_generation_invalidates_cached_plans(tmp_path):
    d = str(tmp_path / "data")
    ref = _build(d)
    region = Block((4, 4), (40, 40))
    ds = Dataset.open(d, engine="pread")
    with ReadService(ds, window_s=0.0) as svc:
        svc.read_batch([Request("t", "T", region)])
        svc.read_batch([Request("t", "T", region)])
        assert svc.stats.cache_hits == 1
        gen0 = ds.generation
        _, dst, _ = reorganize(d, d, "T", _reorg_layout((4, 4)),
                               engine="pread")
        dst.close()
        arr, _ = svc.read_batch([Request("t", "T", region)])[0]
        np.testing.assert_array_equal(arr, ref[region.slices()])
        assert ds.generation == gen0 + 1         # service saw the republish
        assert svc.stats.refreshes >= 1
        assert svc.stats.invalidations >= 1      # stale plans were dropped
        svc.read_batch([Request("t", "T", region)])
        assert svc.stats.cache_hits == 2         # new-generation plan caches
    ds.close()


def test_zero_torn_reads_racing_inplace_reorg(tmp_path):
    """Readers hammer the service while in-place reorganizations commit
    under them: every single result must be byte-identical to the
    reference — a torn read (stale plan against relocated extents) fails
    the equality, not just a flag."""
    d = str(tmp_path / "data")
    ref = _build(d)
    regions = [Block((0, 0), (24, 48)), Block((12, 12), (44, 44)),
               Block((30, 0), (48, 48))]
    ds = Dataset.open(d, engine="pread")
    stop = threading.Event()
    failures, served = [], [0]

    def hammer():
        i = 0
        while not stop.is_set():
            r = regions[i % len(regions)]
            arr, _ = ds_svc.read_batch([Request("t", "T", r)])[0]
            if not np.array_equal(arr, ref[r.slices()]):
                failures.append(i)
            served[0] += 1
            i += 1

    with ReadService(ds, window_s=0.0) as ds_svc:
        t = threading.Thread(target=hammer)
        t.start()
        for k, scheme in enumerate([(4, 4), (2, 8), (8, 2)]):
            _, dst, _ = reorganize(d, d, "T", _reorg_layout(scheme),
                                   engine="pread")
            dst.close()
        time.sleep(0.2)
        stop.set()
        t.join(timeout=30)
        assert not t.is_alive()
        assert not failures, f"torn reads at iterations {failures}"
        assert served[0] > 3
        assert ds_svc.stats.invalidations >= 1
    ds.refresh()
    assert ds.generation == 3
    ds.close()


def test_service_racing_distributed_reorganize(tmp_path):
    """Serving the source while a crash-safe fleet reorganizes it: reads
    stay byte-identical throughout, and the committed destination carries
    the bumped generation so a service over it starts from fresh plans."""
    from repro.distributed.reorg import distributed_reorganize

    src = str(tmp_path / "src")
    dst = str(tmp_path / "dst")
    ref = _build(src)
    region = Block((6, 6), (42, 42))
    ds = Dataset.open(src, engine="pread")
    stop = threading.Event()
    failures = []

    def hammer():
        while not stop.is_set():
            arr, _ = svc.read_batch([Request("t", "T", region)])[0]
            if not np.array_equal(arr, ref[region.slices()]):
                failures.append(1)

    with ReadService(ds, window_s=0.0) as svc:
        t = threading.Thread(target=hammer)
        t.start()
        dst_ds, info = distributed_reorganize(
            src, dst, "T", _reorg_layout((4, 4)), engine="pread",
            num_workers=2)
        stop.set()
        t.join(timeout=30)
        assert not failures, "reads torn while the fleet ran"
    assert dst_ds.index.generation == ds.generation + 1
    arr, _ = dst_ds.read("T", Block((0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    with ReadService(dst_ds, window_s=0.0) as svc2:
        arr, _ = svc2.read_batch([Request("t", "T", region)])[0]
        np.testing.assert_array_equal(arr, ref[region.slices()])
    dst_ds.close()
    ds.close()


# -- per-tenant telemetry feeding the layout policy --------------------------

def test_tenant_tagged_telemetry_aggregates(tmp_path):
    d = str(tmp_path / "data")
    _build(d)
    ds = Dataset.open(d, engine="pread")
    slab = Block((0, 0), (8, 48))                # tenant A: slabs
    column = Block((0, 0), (48, 8))              # tenant B: columns
    with ReadService(ds, window_s=0.0) as svc:
        for _ in range(4):
            svc.read_batch([Request("A", "T", slab)])
            svc.read_batch([Request("B", "T", column)])
    ds.close()

    log = Dataset.open(d, telemetry=False).access_log
    assert len(log.records(tenant="A")) == 4
    assert len(log.records(tenant="B")) == 4
    # the AGGREGATE mix — both tenants' traffic — is what the policy
    # scores; one tenant's records never overwrite another's
    pol = LayoutPolicy.for_dataset(d)
    tenants = {r.tenant for r in pol.records()}
    assert {"A", "B"} <= tenants
    assert len(pol.records_for("T", 2)) == 8

    # per-tenant slices stay exportable as cross-run priors
    pa = log.export_prior(path=str(tmp_path / "prior_a.json"), tenant="A")
    import json
    recs = json.load(open(pa))["records"]
    assert len(recs) == 4 and all(r.get("tn") == "A" for r in recs)
