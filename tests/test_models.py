"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finiteness, plus decode-consistency and the
SSD-vs-recurrence oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import LM
from repro.models.layers import unembed_chunked
from repro.models.params import count_params
from repro.models.ssm import SSMDims, ssd_decode, ssd_defs, ssd_forward
from repro.models.params import materialize
from repro.train import OptimizerConfig, adamw_init, make_train_step

ARCHS = list_archs()


def _batch(cfg, B=2, L=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "tokens":
        b = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L)), jnp.int32)}
    else:
        b = {"frames": jnp.asarray(
            rng.standard_normal((B, L, cfg.d_model)) * 0.05, jnp.float32)}
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    if cfg.family == "vlm":
        b["memory"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_memory_tokens, cfg.d_model)) * 0.05,
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    h, aux, _ = model.hidden(params, batch)
    assert h.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    step = make_train_step(model, OptimizerConfig(warmup_steps=1,
                                                  total_steps=10))
    opt = adamw_init(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(p2)[0]
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a != "hubert-xlarge"])
def test_decode_matches_forward(arch):
    """prefill(L) + decode(token L) == forward(L+1) at the last position."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:       # avoid capacity-drop divergence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    B, L = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, L + 1)), jnp.int32)
    batch = {"tokens": toks[:, :L]}
    if cfg.family == "vlm":
        batch["memory"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_memory_tokens, cfg.d_model)) * 0.05,
            jnp.bfloat16)
    h, _, _ = model.hidden(params, dict(batch, tokens=toks))
    table = params.get("lm_head", params.get("embed"))
    ref = unembed_chunked(h[:, -1:], table, final_cap=cfg.final_cap)
    _, cache = model.prefill(params, batch, cache_len=L + 1)
    dec, _ = model.decode_step(params, cache, toks[:, L:L + 1], jnp.int32(L))
    diff = float(jnp.max(jnp.abs(dec - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert diff / scale < 0.05, (arch, diff, scale)


def test_full_config_param_counts():
    """Full (unreduced) configs must hit their nameplate sizes."""
    expect = {"qwen2.5-3b": (2.8e9, 3.5e9), "yi-9b": (8.0e9, 9.5e9),
              "gemma2-2b": (2.2e9, 3.2e9), "mamba2-780m": (0.7e9, 0.9e9),
              "arctic-480b": (4.3e11, 5.2e11),
              "deepseek-moe-16b": (1.4e10, 1.8e10),
              "llama-3.2-vision-90b": (8.0e10, 9.5e10),
              "hymba-1.5b": (1.2e9, 1.8e9),
              "hubert-xlarge": (0.8e9, 1.2e9),
              "stablelm-3b": (2.5e9, 3.4e9)}
    for arch, (lo, hi) in expect.items():
        n = LM(get_config(arch)).num_params()
        assert lo <= n <= hi, (arch, n)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step state recurrence (f64 oracle)."""
    dims = SSMDims(d_model=32, d_inner=64, headdim=16, d_state=8)
    p = materialize(ssd_defs(dims), jax.random.key(1))
    rng = np.random.default_rng(0)
    B, L = 2, 48
    x = jnp.asarray(rng.standard_normal((B, L, 32)) * 0.3, jnp.float32)
    y_chunked = ssd_forward(p, x, dims, chunk=16)
    # oracle: token-by-token decode from zero state
    cache = {"S": jnp.zeros((B, dims.n_heads, dims.d_state, dims.headdim)),
             "conv": jnp.zeros((B, dims.conv_width - 1, dims.conv_dim))}
    ys = []
    for t in range(L):
        yt, cache = ssd_decode(p, x[:, t:t + 1], cache, dims)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("L,chunk", [(32, 8), (64, 16), (40, 40)])
def test_ssd_chunk_invariance(L, chunk):
    """Property: SSD output must not depend on the chunk size."""
    dims = SSMDims(d_model=16, d_inner=32, headdim=8, d_state=4)
    p = materialize(ssd_defs(dims), jax.random.key(2))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, L, 16)) * 0.3, jnp.float32)
    y1 = ssd_forward(p, x, dims, chunk=chunk)
    y2 = ssd_forward(p, x, dims, chunk=L)        # single chunk
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_sliding_window_restricts_context():
    """A token beyond the window must not influence attention output."""
    from repro.models.attention import attn_defs, attn_forward
    p = materialize(attn_defs(32, 4, 2, 8), jax.random.key(3))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.bfloat16)
    kwargs = dict(n_heads=4, n_kv=2, head_dim=8, causal=True, window=4)
    y1 = attn_forward(p, x, **kwargs)
    x2 = x.at[:, 0].set(100.0)                  # outside window of pos >= 5
    y2 = attn_forward(p, x2, **kwargs)
    np.testing.assert_allclose(np.asarray(y1[:, 8:], np.float32),
                               np.asarray(y2[:, 8:], np.float32),
                               rtol=1e-2, atol=1e-2)
    # within window it must differ
    assert not np.allclose(np.asarray(y1[:, 1], np.float32),
                           np.asarray(y2[:, 1], np.float32), atol=1e-3)
