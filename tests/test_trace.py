"""Workload trace format + capture (ISSUE 8): the trace file must be a
*lossless*, versioned, schema-checked journal.  Roundtrips are bit-exact
(seeded property sweep over synthetic workloads); a future-version file is
rejected instead of misread; a corrupt or truncated file salvages its
complete prefix with a clear error; a 1000-event capture keeps all 1000
events while the live access ring (capacity 256) drops the early ones;
schema violations fail at record time, not replay time."""

import json
import os

import numpy as np
import pytest

from repro.core.blocks import Block, blocks_disjoint, uniform_grid_blocks
from repro.core.layouts import plan_layout
from repro.core.policy import AccessLog
from repro.io import (Dataset, TRACE_VERSION, Trace, TraceCorruptError,
                      TraceError, TraceRecorder, TraceSchemaError,
                      header_for_dataset, load_trace, replay_trace)
from repro.io.trace import (EVENT_KINDS, TraceEvent, TraceHeader,
                            validate_event)


def _seed_dataset(dirpath, var="T", shape=(32, 32, 32), block=(16, 16, 16),
                  seed=0):
    ds = Dataset.create(dirpath, engine="memmap")
    blocks = [b.with_owner(i % 4) for i, b in
              enumerate(uniform_grid_blocks(shape, block))]
    layout = plan_layout("subfiled_fpp", blocks, num_procs=4,
                         global_shape=shape)
    arr = np.random.default_rng(seed).standard_normal(shape) \
        .astype(np.float32)
    ds.write(var, layout, np.float32,
             {cp.chunk.block_id: arr[cp.chunk.slices()]
              for cp in layout.chunks})
    return ds, arr


def _random_region(rng, shape) -> Block:
    lo = tuple(int(rng.integers(0, d)) for d in shape)
    hi = tuple(int(rng.integers(l + 1, d + 1)) for l, d in zip(lo, shape))
    return Block(lo, hi)


def _capture_random_workload(tmp_path, seed: int) -> str:
    """A synthetic workload driven through the real capture hooks."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, 5)) * 8 for _ in range(3))
    src = os.path.join(tmp_path, f"src_{seed}")
    ds, _ = _seed_dataset(src, shape=shape,
                          block=tuple(d // 2 for d in shape), seed=seed)
    path = os.path.join(tmp_path, f"trace_{seed}.jsonl")
    rec = TraceRecorder(path, header_for_dataset(ds, name=f"sweep_{seed}",
                                                 seed=seed))
    ds.attach_trace(rec)
    for _ in range(int(rng.integers(3, 9))):
        ds.read("T", _random_region(rng, shape))
    ds.read_decomposed("T", Block((0, 0, 0), shape), (2, 1, 2))
    ds.read_pattern("T", "plane_xy", num_readers=2,
                    slab_thickness=max(1, shape[2] // 4))
    ds.detach_trace()
    ds.close()
    rec.close()
    return path


# ---------------------------------------------------------------------------
# roundtrip: bit-exact under a seeded sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_roundtrip_bit_exact(tmp_path, seed):
    path = _capture_random_workload(str(tmp_path), seed)
    with open(path, "rb") as f:
        original = f.read()
    tr = load_trace(path)
    resaved = os.path.join(str(tmp_path), "resaved.jsonl")
    tr.save(resaved)
    with open(resaved, "rb") as f:
        assert f.read() == original, "save(load(t)) is not bit-exact"
    # and a second decode of the resave sees identical events
    tr2 = load_trace(resaved)
    assert tr2.events == tr.events
    assert tr2.header == tr.header


def test_event_json_roundtrip_every_kind():
    evs = [
        TraceEvent(kind="read", seq=0, var="T", lo=(0, 0), hi=(4, 4),
                   seconds=0.25, nbytes=64, engine="memmap"),
        TraceEvent(kind="serve", seq=1, var="T", lo=(0, 0), hi=(2, 2),
                   tenant="a"),
        TraceEvent(kind="read_decomposed", seq=2, var="T", lo=(0, 0),
                   hi=(4, 4), params={"scheme": [2, 1]}),
        TraceEvent(kind="read_pattern", seq=3, var="T", lo=(0, 0),
                   hi=(4, 4), params={"pattern": "plane_xy",
                                      "num_readers": 2}),
        TraceEvent(kind="write", seq=4, var="W", lo=(0,), hi=(8,),
                   params={"chunks": [[[0], [8], 0]], "dtype": "float32",
                           "global_shape": [8], "strategy": "chunked"}),
        TraceEvent(kind="stage_submit", seq=5, var="S", lo=(0,), hi=(8,),
                   params={"chunks": [[[0], [8], 0]], "dtype": "float32",
                           "global_shape": [8], "strategy": "chunked",
                           "step": 3}),
        TraceEvent(kind="reorganize", seq=6, var="T",
                   params={"layout": "auto"}),
        TraceEvent(kind="ckpt_save", seq=7,
                   params={"step": 0, "strategy": "auto", "vars": {}}),
        TraceEvent(kind="ckpt_restore", seq=8, params={"step": 0}),
    ]
    assert {e.kind for e in evs} == set(EVENT_KINDS)
    for ev in evs:
        assert TraceEvent.from_json(ev.to_json()) == ev


# ---------------------------------------------------------------------------
# versioning + schema
# ---------------------------------------------------------------------------

def test_future_version_rejected(tmp_path):
    path = os.path.join(str(tmp_path), "future.jsonl")
    hdr = TraceHeader(version=TRACE_VERSION + 1, name="future").to_json()
    with open(path, "w") as f:
        f.write(json.dumps(hdr) + "\n")
    with pytest.raises(TraceError, match="newer than this reader"):
        load_trace(path)
    # salvage must NOT override a version refusal: misreading is worse
    # than failing
    with pytest.raises(TraceError, match="newer than this reader"):
        load_trace(path, salvage=True)


def test_schema_violations_fail_at_record_time(tmp_path):
    path = os.path.join(str(tmp_path), "t.jsonl")
    rec = TraceRecorder(path, TraceHeader(name="x"))
    with pytest.raises(TraceSchemaError):
        rec.record("no_such_kind", var="T", region=Block((0,), (1,)))
    with pytest.raises(TraceSchemaError):          # read without a region
        rec.record("read", var="T")
    with pytest.raises(TraceSchemaError):          # missing required param
        rec.record("read_decomposed", var="T",
                   region=Block((0,), (4,)))
    with pytest.raises(TraceSchemaError):          # inverted region
        validate_event(TraceEvent(kind="read", seq=0, var="T",
                                  lo=(4,), hi=(0,)))
    rec.close()
    assert load_trace(path).events == []           # nothing leaked through


# ---------------------------------------------------------------------------
# corruption: salvage the complete prefix, loudly
# ---------------------------------------------------------------------------

def test_truncated_trace_salvages_prefix(tmp_path):
    path = _capture_random_workload(str(tmp_path), 77)
    full = load_trace(path)
    with open(path, "rb") as f:
        raw = f.read()
    cut = os.path.join(str(tmp_path), "cut.jsonl")
    with open(cut, "wb") as f:
        f.write(raw[:len(raw) - len(raw.splitlines(True)[-1]) + 5])
    with pytest.raises(TraceCorruptError) as ei:
        load_trace(cut)
    assert "intact events salvageable" in str(ei.value)
    salvaged = ei.value.salvaged
    assert salvaged.events == full.events[:-1]
    assert load_trace(cut, salvage=True).events == full.events[:-1]


def test_corrupt_middle_line_salvages_prefix(tmp_path):
    path = _capture_random_workload(str(tmp_path), 78)
    full = load_trace(path)
    lines = open(path).read().splitlines(True)
    keep = 3            # header + 2 events
    bad = os.path.join(str(tmp_path), "bad.jsonl")
    with open(bad, "w") as f:
        f.writelines(lines[:keep])
        f.write("{not json at all\n")
        f.writelines(lines[keep:])
    got = load_trace(bad, salvage=True)
    assert got.events == full.events[:keep - 1]


def test_empty_file_is_corrupt(tmp_path):
    path = os.path.join(str(tmp_path), "empty.jsonl")
    open(path, "w").close()
    with pytest.raises(TraceCorruptError):
        load_trace(path)


# ---------------------------------------------------------------------------
# empty trace: header only, replays as a no-op
# ---------------------------------------------------------------------------

def test_empty_trace_replays_as_noop(tmp_path):
    path = os.path.join(str(tmp_path), "noop.jsonl")
    TraceRecorder(path, TraceHeader(name="noop", seed=5)).close()
    tr = load_trace(path)
    assert tr.events == []
    r = replay_trace(tr, os.path.join(str(tmp_path), "rp"))
    assert r.counts == {}
    assert r.bytes_verified == 0
    assert r.events == 0


# ---------------------------------------------------------------------------
# satellite 1: capture is lossless past the 256-record access ring
# ---------------------------------------------------------------------------

def test_thousand_event_capture_is_lossless(tmp_path):
    src = os.path.join(str(tmp_path), "src")
    ds, _ = _seed_dataset(src)
    path = os.path.join(str(tmp_path), "big.jsonl")
    rec = TraceRecorder(path, header_for_dataset(ds, name="big", seed=9))
    ds.attach_trace(rec)
    regions = [Block((0, 0, 2 * (i % 16)), (32, 32, 2 * (i % 16) + 2))
               for i in range(1000)]
    for region in regions:
        ds.read("T", region)
    ds.close()          # flushes the access log
    rec.close()
    # the ring dropped the early records...
    log = AccessLog(src)
    assert len(log.records()) <= 256 < 1000
    # ...the trace kept every one, in order, with the right regions
    tr = load_trace(path)
    assert len(tr.events) == 1000
    assert [e.seq for e in tr.events] == list(range(1000))
    assert [(e.lo, e.hi) for e in tr.events] == \
        [(r.lo, r.hi) for r in regions]
    assert all(e.kind == "read" and e.var == "T" for e in tr.events)


# ---------------------------------------------------------------------------
# scaled replay: the boundary map must preserve coverage and disjointness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("factor", [2, 3])
def test_scaled_trace_stays_valid(tmp_path, factor):
    path = _capture_random_workload(str(tmp_path), 100 + factor)
    tr = load_trace(path)
    sc = tr.scaled(factor)
    assert sc.header.name.endswith(f"@1/{factor}")
    for var, meta in sc.header.variables.items():
        shape = tuple(meta["shape"])
        full_shape = tuple(tr.header.variables[var]["shape"])
        assert shape == tuple(-(-d // factor) for d in full_shape)
        chunks = [Block(tuple(lo), tuple(hi)) for lo, hi, _sf
                  in meta["chunks"]]
        assert blocks_disjoint(chunks)
        assert sum(c.volume for c in chunks) == int(np.prod(shape))
    for ev in sc.events:        # every surviving region fits the new shape
        if ev.lo is None:
            continue
        shape = tuple(sc.header.variables[ev.var]["shape"])
        assert all(0 <= l < h <= d
                   for l, h, d in zip(ev.lo, ev.hi, shape))
    # and the scaled trace actually replays clean
    r = replay_trace(sc, os.path.join(str(tmp_path), "rp_scaled"))
    assert r.bytes_verified > 0


def test_save_validates_events(tmp_path):
    bad = Trace(header=TraceHeader(name="bad"),
                events=[TraceEvent(kind="read", seq=0, var="")])
    with pytest.raises(TraceSchemaError):
        bad.save(os.path.join(str(tmp_path), "bad.jsonl"))
