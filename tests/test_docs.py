"""Tier-1 wrapper around the docs gate: README/docs relative links must
resolve and every ``>>>`` snippet in the markdown must run (the same check
CI's docs job performs via ``tools/check_docs.py``)."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_links_and_doctests():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_docs.py")],
        capture_output=True, text=True, env=env, cwd=ROOT)
    assert proc.returncode == 0, \
        f"docs check failed:\n{proc.stdout}\n{proc.stderr}"


def test_docs_exist():
    for f in ("docs/architecture.md", "docs/engine_selection.md",
              "README.md"):
        assert os.path.exists(os.path.join(ROOT, f)), f
