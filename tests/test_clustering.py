"""Tests for the extended Berger–Rigoutsos clustering (Algorithm 1)."""

import numpy as np

from repro.core.blocks import (Block, blocks_disjoint, total_volume,
                               uniform_grid_blocks, simulate_load_balance)
from repro.core.clustering import cluster_blocks, merged_block_counts


def _check_invariants(blocks, clusters, fully_filled=True):
    # every block in exactly one cluster
    seen = [m.block_id for c in clusters for m in c.members]
    assert sorted(seen) == sorted(b.block_id for b in blocks)
    # clusters fully filled (Algorithm 1's termination criterion)
    if fully_filled:
        for c in clusters:
            assert c.cuboid.volume == sum(m.volume for m in c.members)
        assert blocks_disjoint([c.cuboid for c in clusters])
    # volume conservation
    assert sum(sum(m.volume for m in c.members) for c in clusters) \
        == total_volume(blocks)


def test_single_block():
    b = Block((0, 0, 0), (4, 4, 4), owner=0, block_id=0)
    cls = cluster_blocks([b])
    assert len(cls) == 1 and cls[0].cuboid.shape == (4, 4, 4)


def test_full_slab_merges_to_one():
    blocks = uniform_grid_blocks((64, 64, 16), (16, 16, 16))
    cls = cluster_blocks(blocks)
    assert len(cls) == 1
    _check_invariants(blocks, cls)


def test_two_separated_slabs():
    blks, bid = [], 0
    for base in (0, 6):
        for i in range(2):
            for j in range(4):
                blks.append(Block(((base + i) * 8, j * 8, 0),
                                  ((base + i + 1) * 8, (j + 1) * 8, 8),
                                  owner=0, block_id=bid))
                bid += 1
    cls = cluster_blocks(blks)
    assert len(cls) == 2
    _check_invariants(blks, cls)


def test_l_shape():
    blks = [Block((0, 0, 0), (1, 1, 1), 0, 0),
            Block((1, 0, 0), (2, 1, 1), 0, 1),
            Block((0, 1, 0), (1, 2, 1), 0, 2)]
    cls = cluster_blocks(blks)
    assert len(cls) == 2
    _check_invariants(blks, cls)


def test_checkerboard_cannot_merge():
    """Isolated alternating blocks have no fully-filled super-cuboid."""
    blks = []
    bid = 0
    for i in range(4):
        for j in range(4):
            if (i + j) % 2 == 0:
                blks.append(Block((i * 2, j * 2), ((i + 1) * 2, (j + 1) * 2),
                                  0, bid))
                bid += 1
    cls = cluster_blocks(blks)
    assert len(cls) == len(blks)
    _check_invariants(blks, cls)


def test_property_random_distributions():
    """Property sweep: invariants hold for random load-balanced ownerships,
    and merging never increases the block count."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        nb = int(rng.integers(2, 6))
        blocks = uniform_grid_blocks((nb * 16, 64, 32), (16, 16, 16))
        lb = simulate_load_balance(blocks, num_procs=5, seed=seed)
        for p in range(5):
            mine = [b for b in lb if b.owner == p]
            if not mine:
                continue
            cls = cluster_blocks(mine)
            _check_invariants(mine, cls)
            assert len(cls) <= len(mine)


def test_non_uniform_blocks():
    """The loosened assumption: mixed block shapes still cluster correctly."""
    blks = [Block((0, 0, 0), (4, 8, 8), 0, 0),      # tall
            Block((4, 0, 0), (8, 8, 8), 0, 1),      # fills to a cube
            Block((16, 0, 0), (24, 4, 8), 0, 2)]    # separate slab
    cls = cluster_blocks(blks)
    _check_invariants(blks, cls)
    assert len(cls) == 2


def test_max_clusters_cap():
    blocks = uniform_grid_blocks((64, 64, 16), (8, 8, 8))
    lb = simulate_load_balance(blocks, num_procs=3, rounds=6,
                               exchange_frac=0.5, locality_bias=0.1, seed=1)
    mine = [b for b in lb if b.owner == 0]
    capped = cluster_blocks(mine, max_clusters=4)
    assert len(capped) <= 4
    # capped clusters may not be fully filled; membership still partitions
    seen = [m.block_id for c in capped for m in c.members]
    assert sorted(seen) == sorted(b.block_id for b in mine)


def test_paper_metric_direction():
    """Fig. 8 / §4.3: merging reduces ~10 blocks/proc to a few."""
    blocks = uniform_grid_blocks((256, 256, 256), (32, 32, 64))
    lb = simulate_load_balance(blocks, num_procs=50, seed=0)
    ratios = []
    for p in range(50):
        mine = [b for b in lb if b.owner == p]
        if len(mine) >= 4:
            o, m = merged_block_counts(mine)
            ratios.append(m / o)
    assert np.mean(ratios) < 0.75   # at least ~25% reduction on average
