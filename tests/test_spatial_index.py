"""Property tests for the spatial chunk index and the read planner (ISSUE 1).

The indexed read path must be observationally identical to the seed's
brute-force linear scan: byte-identical arrays, identical chunks_touched,
across every layout strategy, random regions (including empty intersections)
and both execution engines; the persisted v2 index must round-trip.
"""

import json
import os

import numpy as np
import pytest

from repro.core import STRATEGIES, plan_layout, simulate_load_balance, \
    uniform_grid_blocks
from repro.core.blocks import Block
from repro.io import (Dataset, SpatialChunkIndex, build_read_plan,
                      linear_candidates)
from repro.io.format import DatasetIndex

GLOBAL = (64, 64, 64)
BLOCK = (16, 16, 16)
NPROCS = 8


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(3)
    blocks = simulate_load_balance(uniform_grid_blocks(GLOBAL, BLOCK),
                                   num_procs=NPROCS, seed=7)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    return blocks, data, ref


def _write(d, name, plan, data):
    ds = Dataset.create(d)
    ds.write(name, plan, np.float32, data)
    ds.close()
    return ds.index


def _random_regions(rng, n=12):
    regions = []
    for _ in range(n):
        lo = tuple(int(rng.integers(0, g - 1)) for g in GLOBAL)
        hi = tuple(int(rng.integers(l + 1, g + 1))
                   for l, g in zip(lo, GLOBAL))
        regions.append(Block(lo, hi))
    # degenerate slivers and exact chunk-aligned regions
    regions.append(Block((0, 0, 0), (1, 1, 1)))
    regions.append(Block((16, 16, 16), (32, 32, 32)))
    regions.append(Block((63, 63, 63), (64, 64, 64)))
    return regions


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_indexed_reads_match_linear_oracle(tmp_path, world, strategy):
    blocks, data, ref = world
    d = str(tmp_path / strategy)
    plan = plan_layout(strategy, blocks, num_procs=NPROCS,
                       procs_per_node=4, global_shape=GLOBAL,
                       reorg_scheme=(2, 2, 2), num_stagers=2)
    wdata = data
    if strategy == "merged_node":
        from repro.io import gather_to_nodes
        _, wdata, _ = gather_to_nodes(blocks, data, 4)
    _write(d, "B", plan, wdata)
    ds = Dataset(d)
    rows = ds.index.var_rows("B")
    sp = ds.index.spatial_index("B")
    rng = np.random.default_rng(11)
    for region in _random_regions(rng):
        oracle = linear_candidates(rows, region)
        got = sp.query(region.lo, region.hi)
        assert np.array_equal(got, oracle)
        arr, st = ds.read("B", region)
        np.testing.assert_array_equal(arr, ref[region.slices()])
        assert st.chunks_touched == len(oracle)
        arr2, st2 = ds.read("B", region, engine="pread")
        np.testing.assert_array_equal(arr2, ref[region.slices()])
        assert st2.chunks_touched == st.chunks_touched
        assert st2.runs == st.runs


def test_empty_intersection_region(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "empty")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=(128, 64, 64))
    _write(d, "B", plan, data)
    ds = Dataset(d)
    region = Block((100, 0, 0), (120, 8, 8))    # past every stored chunk
    arr, st = ds.read("B", region)
    assert st.chunks_touched == 0 and st.runs == 0 and st.bytes_read == 0
    plan_ = ds.plan_read("B", region)
    assert plan_.num_chunks == 0 and plan_.num_groups == 0


def test_plan_structure_invariants(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "inv")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    ds = Dataset(d)
    rng = np.random.default_rng(5)
    for region in _random_regions(rng, n=6):
        rp = ds.plan_read("B", region)
        if rp.num_chunks == 0:
            continue
        # execution order: sorted by (subfile, offset)
        key = rp.subfiles * (1 << 48) + rp.file_lo
        assert np.all(np.diff(key) > 0)
        # groups cover contiguouly ascending spans; runs never exceed the
        # per-chunk analytic sum and never undercut the group count
        assert rp.runs <= int(rp.chunk_runs.sum())
        assert rp.runs >= rp.num_groups
        inter_vol = sum(
            region.intersect(ds.index.chunks[i].block).volume
            for i in rp.rec_ids)
        assert rp.bytes_needed == inter_vol * 4
        gb = rp.group_bounds
        assert gb[0] == 0 and gb[-1] == rp.num_chunks
        for g in range(rp.num_groups):
            s, e = gb[g], gb[g + 1]
            assert np.all(rp.subfiles[s:e] == rp.subfiles[s])
            assert np.all(rp.file_lo[s + 1:e] >= rp.file_hi[s:e - 1])


def test_candidate_narrowing_matches_full_probe(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "narrow")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    ds = Dataset(d)
    region = Block((4, 4, 4), (60, 60, 60))
    sp = ds.index.spatial_index("B")
    cand = sp.query(region.lo, region.hi)
    sub = Block((10, 10, 10), (30, 50, 20))
    direct = build_read_plan(ds.index, "B", sub)
    narrowed = build_read_plan(ds.index, "B", sub, candidates=cand)
    assert np.array_equal(direct.rec_ids, narrowed.rec_ids)
    st = ds.read_decomposed("B", region, (2, 2, 1))
    assert st.bytes_read == region.volume * 4


def test_spatial_index_persistence_roundtrip(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "persist")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    with open(os.path.join(d, "index.json")) as f:
        payload = json.load(f)
    assert payload["version"] == 4
    assert "B" in payload["spatial"]
    ds = Dataset(d)
    # loaded (persisted) index answers identically to a fresh rebuild
    rows = ds.index.var_rows("B")
    fresh = SpatialChunkIndex(rows.los, rows.his)
    rng = np.random.default_rng(2)
    for region in _random_regions(rng, n=6):
        a = ds.index.spatial_index("B").query(region.lo, region.hi)
        b = fresh.query(region.lo, region.hi)
        assert np.array_equal(a, b)


def test_v2_v3_index_loads_transparently_byte_identical(tmp_path, world):
    """Index v4 added per-chunk codec fields; a raw (uncompressed) dataset
    emits none of them, so a v3 file — and a v2 file once the per-record
    CRCs are stripped — must load transparently and read back the exact
    bytes a v4 session wrote."""
    blocks, data, ref = world
    d = str(tmp_path / "downlevel")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    path = os.path.join(d, "index.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["version"] == 4
    # v3: same records, pre-codec version stamp
    payload["version"] = 3
    with open(path, "w") as f:
        json.dump(payload, f)
    ds = Dataset(d)
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    ds.close()
    # v2: additionally pre-CRC — verify_checksums skips what it can't check
    for rec in payload["chunks"]:
        rec.pop("crc", None)
    payload["version"] = 2
    with open(path, "w") as f:
        json.dump(payload, f)
    ds = Dataset(d)
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    checked, bad = ds.verify_checksums("B")
    assert checked == 0 and bad == []
    ds.close()


def test_v1_index_without_spatial_payload_still_reads(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "v1")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    path = os.path.join(d, "index.json")
    with open(path) as f:
        payload = json.load(f)
    payload.pop("spatial")
    payload["version"] = 1
    with open(path, "w") as f:
        json.dump(payload, f)
    ds = Dataset(d)
    arr, st = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)


def test_appended_variable_invalidates_cache(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "append")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    sess = Dataset.create(d)
    sess.write("B", plan, np.float32, data)
    idx = sess.index
    _ = idx.spatial_index("B")           # warm the cache
    data2 = {k: v * 3 for k, v in data.items()}
    sess.write("E", plan, np.float32, data2)
    sess.close()
    # the same index object must see the appended records
    sub = Block((3, 3, 3), (40, 41, 42))
    got = idx.spatial_index("E").query(sub.lo, sub.hi)
    oracle = linear_candidates(idx.var_rows("E"), sub)
    assert np.array_equal(got, oracle)
    ds = Dataset(d)
    arr, _ = ds.read("E", sub)
    np.testing.assert_array_equal(arr, ref[sub.slices()] * 3)


def test_interval_fallback_for_irregular_chunks():
    """Wildly mixed chunk sizes force the sorted-interval organization; the
    query answers must still match the oracle."""
    rng = np.random.default_rng(9)
    los, his = [], []
    x = 0
    for _ in range(300):
        w = int(rng.integers(1, 200))
        y = int(rng.integers(0, 50))
        h = int(rng.integers(1, 300))
        los.append((x, y))
        his.append((x + w, y + h))
        x += max(1, w // 3)
    los = np.array(los)
    his = np.array(his)
    sp = SpatialChunkIndex(los, his)
    for _ in range(30):
        qlo = (int(rng.integers(0, x)), int(rng.integers(0, 300)))
        qhi = (qlo[0] + int(rng.integers(1, 200)),
               qlo[1] + int(rng.integers(1, 200)))
        got = sp.query(qlo, qhi)
        oracle = np.flatnonzero(np.all(los < qhi, axis=1)
                                & np.all(his > qlo, axis=1))
        assert np.array_equal(got, oracle)
