"""Unit tests for the distributed-reorg journal layer (ISSUE 6 tentpole):
WritePlan (de)serialization, group-aligned unit partitioning, the lease
protocol under an injected clock, retry backoff, checksum validation and
index-version transparency.  The multi-process SIGKILL matrix lives in
``test_kill_matrix.py``; everything here is single-process."""

import json
import os

import numpy as np
import pytest

from repro.core import plan_layout, simulate_load_balance, uniform_grid_blocks
from repro.core.blocks import Block
from repro.distributed.reorg import validate_journal, with_retry, worker_main
from repro.io import Dataset, build_write_plan, reorganize, subset_write_plan
from repro.io.format import DatasetIndex, extent_checksum, subfile_name
from repro.io.journal import (REORG_JOURNAL_NAME, ReorgJournal, WorkUnit,
                              deserialize_write_plan, partition_unit_rows,
                              serialize_write_plan)

GLOBAL = (16, 16, 16)


class FakeClock:
    def __init__(self, t=1000.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _world(seed=11, nprocs=2):
    blocks = simulate_load_balance(uniform_grid_blocks(GLOBAL, (8, 8, 8)),
                                   num_procs=nprocs, seed=seed)
    rng = np.random.default_rng(seed)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    return blocks, data, ref


def _write_src(tmp_path, blocks, data):
    src = str(tmp_path / "src")
    ds = Dataset.create(src)
    ds.write("B", plan_layout("subfiled_fpp", blocks, num_procs=2,
                              global_shape=GLOBAL), np.float32, data)
    ds.close()
    return src


def _dst_plan(blocks):
    # align=4096 pads between extents, so nothing coalesces: 8 chunks ->
    # 8 groups, enough to cut into several work units
    layout = plan_layout("chunked", blocks, num_procs=2, global_shape=GLOBAL)
    return build_write_plan(layout, "B", np.float32, align=4096)


# -- WritePlan (de)serialization ---------------------------------------------

def test_write_plan_roundtrip():
    blocks, _, _ = _world()
    plan = _dst_plan(blocks)
    d = json.loads(json.dumps(serialize_write_plan(plan)))  # via real JSON
    back = deserialize_write_plan(d)
    assert back.var == plan.var and back.dtype == plan.dtype
    for f in ("chunk_ids", "chunk_los", "chunk_his", "writers", "subfiles",
              "file_lo", "file_hi", "nbytes", "group_bounds"):
        np.testing.assert_array_equal(getattr(back, f), getattr(plan, f))
    assert back.file_sizes == plan.file_sizes        # int keys restored
    assert back.align == plan.align
    assert back.span_bytes == plan.span_bytes
    assert back.layout.strategy == plan.layout.strategy
    assert len(back.layout.chunks) == len(plan.layout.chunks)
    # layout.chunks must stay indexable by chunk_id
    for row in range(back.num_chunks):
        cid = int(back.chunk_ids[row])
        assert back.layout.chunks[cid].chunk.block_id == cid


def test_subset_of_deserialized_plan_matches_original():
    blocks, _, _ = _world()
    plan = _dst_plan(blocks)
    back = deserialize_write_plan(serialize_write_plan(plan))
    rows = np.arange(plan.num_chunks // 2)
    a, b = subset_write_plan(plan, rows), subset_write_plan(back, rows)
    np.testing.assert_array_equal(a.file_lo, b.file_lo)
    np.testing.assert_array_equal(a.group_bounds, b.group_bounds)
    assert a.file_sizes == b.file_sizes


# -- unit partitioning -------------------------------------------------------

def test_partition_covers_rows_exactly_once_and_group_aligned():
    blocks, _, _ = _world()
    plan = _dst_plan(blocks)
    for num_units in (1, 2, 3, plan.num_groups, plan.num_groups + 5):
        units = partition_unit_rows(plan, num_units)
        assert len(units) == min(num_units, plan.num_groups)
        flat = [r for rows in units for r in rows]
        assert flat == list(range(plan.num_chunks))   # contiguous, complete
        # every unit boundary is a coalesced-group boundary
        bounds = set(int(b) for b in plan.group_bounds)
        pos = 0
        for rows in units:
            assert pos in bounds
            pos += len(rows)


def test_partition_empty_plan():
    blocks, _, _ = _world()
    plan = _dst_plan(blocks)
    empty = subset_write_plan(plan, np.array([], dtype=np.int64))
    assert partition_unit_rows(empty, 4) == []


# -- the lease protocol ------------------------------------------------------

def _journal(tmp_path, clock, lease_timeout_s=10.0, num_units=3):
    blocks, data, _ = _world()
    src = _write_src(tmp_path, blocks, data)
    plan = _dst_plan(blocks)
    dst = str(tmp_path / "dst")
    j = ReorgJournal.create(dst, plan, src, num_units=num_units,
                            lease_timeout_s=lease_timeout_s, clock=clock)
    return j, plan, src, dst


def test_journal_create_refuses_double_create(tmp_path):
    clk = FakeClock()
    j, plan, src, dst = _journal(tmp_path, clk)
    with pytest.raises(FileExistsError):
        ReorgJournal.create(dst, plan, src, num_units=3, clock=clk)
    assert j.spec()["src_dir"] == os.path.abspath(src)
    assert j.spec()["var"] == "B"
    assert not j.done()


def test_claim_renew_complete_happy_path(tmp_path):
    clk = FakeClock()
    j, plan, _, _ = _journal(tmp_path, clk, num_units=2)
    u = j.claim("w0")
    assert u is not None and u.state == "leased" and u.attempt == 1
    assert u.lease_expires == pytest.approx(clk() + 10.0)
    assert j.renew("w0", u.unit_id)
    crcs = {int(r): 0 for r in u.rows}
    assert j.complete("w0", u.unit_id, crcs)
    u2 = j.claim("w0")
    assert u2.unit_id != u.unit_id
    assert j.complete("w0", u2.unit_id, {int(r): 0 for r in u2.rows})
    assert j.claim("w0") is None
    assert j.done()
    states = {u.unit_id: u.state for u in j.units()}
    assert set(states.values()) == {"done"}


def test_expired_lease_is_reclaimed_and_stale_worker_refused(tmp_path):
    clk = FakeClock()
    j, _, _, _ = _journal(tmp_path, clk, lease_timeout_s=10.0, num_units=1)
    u = j.claim("w0")
    clk.advance(11.0)                       # w0 goes silent past the deadline
    u2 = j.claim("w1")
    assert u2 is not None and u2.unit_id == u.unit_id
    assert u2.worker == "w1" and u2.attempt == 2
    # the stale holder must abandon: renew and complete both refused
    assert not j.renew("w0", u.unit_id)
    assert not j.complete("w0", u.unit_id, {})
    # the new holder proceeds normally
    assert j.renew("w1", u2.unit_id)
    assert j.complete("w1", u2.unit_id, {int(r): 0 for r in u2.rows})
    events = [e["event"] for e in j.load()["events"]]
    assert "lease_expired" in events


def test_live_lease_is_not_stolen(tmp_path):
    clk = FakeClock()
    j, _, _, _ = _journal(tmp_path, clk, lease_timeout_s=10.0, num_units=1)
    j.claim("w0")
    clk.advance(5.0)
    assert j.claim("w1") is None            # under a live lease elsewhere


def test_renew_extends_deadline(tmp_path):
    clk = FakeClock()
    j, _, _, _ = _journal(tmp_path, clk, lease_timeout_s=10.0, num_units=1)
    u = j.claim("w0")
    clk.advance(8.0)
    assert j.renew("w0", u.unit_id)
    clk.advance(8.0)                        # 16s after claim, 8s after renew
    assert j.claim("w1") is None


def test_reset_units_clears_completion(tmp_path):
    clk = FakeClock()
    j, _, _, _ = _journal(tmp_path, clk, num_units=1)
    u = j.claim("w0")
    j.complete("w0", u.unit_id, {int(r): 123 for r in u.rows})
    assert j.done()
    j.reset_units([u.unit_id], reason="validation")
    assert not j.done()
    fresh = j.units()[0]
    assert fresh.state == "pending" and fresh.checksums == {}
    assert any(e["event"] == "reset" for e in j.load()["events"])


def test_monitor_seeded_from_persisted_heartbeats(tmp_path):
    clk = FakeClock()
    j, _, _, _ = _journal(tmp_path, clk, lease_timeout_s=10.0, num_units=2)
    j.claim("w0")
    clk.advance(6.0)
    j.claim("w1")
    mon = j.monitor()
    assert mon.dead_hosts() == []
    clk.advance(6.0)                        # w0 silent 12s, w1 silent 6s
    mon = j.monitor()
    assert mon.dead_hosts() == ["w0"]
    assert mon.alive_hosts() == ["w1"]


# -- with_retry --------------------------------------------------------------

def test_with_retry_exponential_backoff():
    calls, naps = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("blip")
        return "ok"

    assert with_retry(flaky, attempts=4, backoff_s=0.1,
                      sleep=naps.append) == "ok"
    assert len(calls) == 3
    assert naps == [pytest.approx(0.1), pytest.approx(0.2)]


def test_with_retry_raises_after_budget():
    naps = []

    def dead():
        raise OSError("gone")

    with pytest.raises(OSError, match="gone"):
        with_retry(dead, attempts=3, backoff_s=0.01, sleep=naps.append)
    assert len(naps) == 2                   # no sleep after the last attempt


def test_with_retry_unlisted_exception_propagates_immediately():
    calls = []

    def boom():
        calls.append(1)
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        with_retry(boom, attempts=5, sleep=lambda s: None)
    assert len(calls) == 1


# -- worker + validation, in-process -----------------------------------------

def test_worker_main_drains_journal_and_validates(tmp_path):
    clk = FakeClock()
    j, plan, _, dst = _journal(tmp_path, clk, num_units=3)
    stats = worker_main(dst, "w0")
    assert stats["units_done"] == 3 and stats["units_lost"] == 0
    assert stats["chunks_gathered"] == plan.num_chunks
    assert j.done()
    assert validate_journal(dst, plan, j) == []


def test_validation_flags_corrupt_unit_and_redo_heals(tmp_path):
    clk = FakeClock()
    j, plan, _, dst = _journal(tmp_path, clk, num_units=3)
    worker_main(dst, "w0")
    victim = j.units()[1]
    row = int(victim.rows[0])
    path = os.path.join(dst, subfile_name(int(plan.subfiles[row])))
    with open(path, "r+b") as f:            # flip one byte of the extent
        f.seek(int(plan.file_lo[row]))
        b = f.read(1)
        f.seek(int(plan.file_lo[row]))
        f.write(bytes([b[0] ^ 0xFF]))
    assert validate_journal(dst, plan, j) == [victim.unit_id]
    j.reset_units([victim.unit_id])
    worker_main(dst, "w1")                  # a fresh worker redoes only it
    assert validate_journal(dst, plan, j) == []


def test_validation_flags_missing_checksum_rows(tmp_path):
    clk = FakeClock()
    j, plan, _, dst = _journal(tmp_path, clk, num_units=2)
    u = j.claim("w0")
    j.complete("w0", u.unit_id, {})         # done, but no CRCs recorded
    assert validate_journal(dst, plan, j) == [u.unit_id]


# -- checksums end to end ----------------------------------------------------

def test_reorganize_stamps_checksums_and_verify_passes(tmp_path):
    blocks, data, _ = _world()
    src = _write_src(tmp_path, blocks, data)
    dst = str(tmp_path / "dst")
    _, ds, _ = reorganize(src, dst, "B", layout="auto")
    try:
        recs = [r for r in ds.index.chunks if r.var == "B"]
        assert all(r.checksum is not None for r in recs)
        checked, bad = ds.verify_checksums()
        assert checked == len(recs) and bad == []
    finally:
        ds.close()


def test_verify_checksums_detects_corruption(tmp_path):
    blocks, data, _ = _world()
    src = _write_src(tmp_path, blocks, data)
    dst = str(tmp_path / "dst")
    _, ds, _ = reorganize(src, dst, "B", layout="auto")
    rec = ds.index.chunks[0]
    ds.close()
    path = os.path.join(dst, subfile_name(rec.subfile))
    with open(path, "r+b") as f:
        f.seek(rec.offset)
        b = f.read(1)
        f.seek(rec.offset)
        f.write(bytes([b[0] ^ 0xFF]))
    ds = Dataset.open(dst)
    try:
        checked, bad = ds.verify_checksums()
        assert len(bad) == 1 and checked >= 1
    finally:
        ds.close()


def test_v2_index_without_checksums_reads_transparently(tmp_path):
    blocks, data, ref = _world()
    src = _write_src(tmp_path, blocks, data)
    # rewrite the index as version 2 with the crc fields stripped
    p = os.path.join(src, "index.json")
    with open(p) as f:
        payload = json.load(f)
    payload["version"] = 2
    for rec in payload["chunks"]:
        rec.pop("crc", None)
    with open(p, "w") as f:
        json.dump(payload, f)
    ds = Dataset.open(src)
    try:
        assert all(r.checksum is None for r in ds.index.chunks)
        arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
        np.testing.assert_array_equal(arr, ref)
        checked, bad = ds.verify_checksums()
        assert checked == 0 and bad == []   # nothing to check, nothing wrong
    finally:
        ds.close()


def test_reorganize_learns_chunk_overhead(tmp_path):
    from repro.core.cost_model import load_reorg_stats
    from repro.core.policy import LayoutPolicy
    blocks, data, _ = _world()
    src = _write_src(tmp_path, blocks, data)
    assert load_reorg_stats(src) is None
    _, ds, _ = reorganize(src, str(tmp_path / "dst"), "B", layout="auto")
    ds.close()
    st = load_reorg_stats(src)
    assert st is not None
    assert st.num_observations == 1 and st.chunk_overhead_s > 0
    # the next layout decision over this dataset prices reorganization
    # with the measured overhead, not the static default
    pol = LayoutPolicy.for_dataset(src)
    assert pol.chunk_overhead_s == pytest.approx(st.chunk_overhead_s)


def test_unit_json_roundtrip():
    u = WorkUnit(unit_id=3, rows=[4, 5, 6], state="done", worker="w1",
                 lease_expires=12.5, attempt=2, checksums={4: 9, 5: 8, 6: 7})
    back = WorkUnit.from_json(json.loads(json.dumps(u.to_json())))
    assert back == u
