"""Unit tests for the trip-count-aware HLO cost analyzer."""

from repro.launch.hlo_analysis import analyze_hlo

SAMPLE = """
HloModule jit_step, is_scheduled=true

%fused_dus (param_0.1: f32[8,16,32], param_1.1: f32[1,16,32], param_2.1: s32[]) -> f32[8,16,32] {
  %param_0.1 = f32[8,16,32]{2,1,0} parameter(0)
  %param_1.1 = f32[1,16,32]{2,1,0} parameter(1)
  %param_2.1 = s32[] parameter(2)
  ROOT %dus = f32[8,16,32]{2,1,0} dynamic-update-slice(%param_0.1, %param_1.1, %param_2.1)
}

%body (arg: (s32[], f32[16,32], f32[8,32,32])) -> (s32[], f32[16,32], f32[8,32,32]) {
  %arg = (s32[], f32[16,32], f32[8,32,32]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %x = f32[16,32]{1,0} get-tuple-element(%arg), index=1
  %ws = f32[8,32,32]{2,1,0} get-tuple-element(%arg), index=2
  %w = f32[32,32]{1,0} dynamic-slice(%ws, %iv), dynamic_slice_sizes={1,32,32}
  %y = f32[16,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %r = f32[16,32]{1,0} all-reduce(%y), replica_groups={}, to_apply=%body
  %one = s32[] constant(1)
  %ivn = s32[] add(%iv, %one)
  ROOT %out = (s32[], f32[16,32], f32[8,32,32]) tuple(%ivn, %r, %ws)
}

%cond (arg2: (s32[], f32[16,32], f32[8,32,32])) -> pred[] {
  %arg2 = (s32[], f32[16,32], f32[8,32,32]) parameter(0)
  %iv2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%iv2, %n), direction=LT
}

ENTRY %main (p0: f32[16,32], p1: f32[8,32,32]) -> f32[16,32] {
  %p0 = f32[16,32]{1,0} parameter(0)
  %p1 = f32[8,32,32]{2,1,0} parameter(1)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,32], f32[8,32,32]) tuple(%zero, %p0, %p1)
  %loop = (s32[], f32[16,32], f32[8,32,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %res = f32[16,32]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_trip_count_from_backend_config():
    c = analyze_hlo(SAMPLE)
    assert c.while_trips == {"loop": 8}


def test_dot_flops_multiplied_by_trips():
    c = analyze_hlo(SAMPLE)
    # dot: 2 * out(16*32) * k(32) = 32768 flops, x8 trips
    assert c.flops == 8 * 2 * 16 * 32 * 32


def test_collective_bytes():
    c = analyze_hlo(SAMPLE)
    # all-reduce of f32[16,32] = 2048 B, ring 2x, x8 trips
    assert c.collective_bytes == 8 * 2 * 2048
    assert c.collectives["all-reduce"]["count"] == 8


def test_dynamic_slice_counts_slice_only():
    c = analyze_hlo(SAMPLE)
    # the (8,32,32) weight stack must NOT be charged 8x32KB per trip for
    # the dynamic-slice; each trip reads ~1 slice (32x32x4 = 4KB x2)
    per_trip_ds = 2 * 32 * 32 * 4
    assert c.bytes < 8 * (per_trip_ds + 5 * 16 * 32 * 4 + 8 * 32 * 32 * 4)


def test_trip_count_fallback_from_condition():
    # strip the backend_config so the condition constant is used
    sample = SAMPLE.replace(
        ', backend_config={"known_trip_count":{"n":"8"}}', "")
    c = analyze_hlo(sample)
    assert c.while_trips == {"loop": 8}
