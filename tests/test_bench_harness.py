"""Exit-code contract of the benchmark harness (ISSUE 5 satellite).

The CI bench-smoke matrix runs ``python -m benchmarks.run <section>`` and
trusts the exit code.  That trust has two historical holes: a leg raising
``SystemExit(0)`` mid-crash would fake success, and a typo'd section
filter would "pass" by running zero legs.  These tests pin the contract.
"""

import pytest

from benchmarks import run as bench_run


def _with_sections(monkeypatch, sections):
    monkeypatch.setattr(bench_run, "SECTIONS", sections)


def test_all_legs_pass_exits_zero(monkeypatch, capsys):
    _with_sections(monkeypatch, [("ok_a", lambda tmp: None),
                                 ("ok_b", lambda tmp: None)])
    assert bench_run.main([]) == 0
    out = capsys.readouterr().out
    assert "FAILED" not in out


def test_raising_leg_exits_nonzero_but_runs_the_rest(monkeypatch, capsys):
    ran = []

    def boom(tmp):
        raise ValueError("leg crashed")

    _with_sections(monkeypatch, [("boom", boom),
                                 ("after", lambda tmp: ran.append(1))])
    assert bench_run.main([]) == 1
    assert ran == [1]                      # the crash did not stop the run
    assert "boom/FAILED,0,ValueError" in capsys.readouterr().out


def test_leg_calling_sys_exit_zero_still_fails(monkeypatch, capsys):
    """A benchmark that dies via sys.exit(0) is a crashed leg, not a pass."""
    def sneaky(tmp):
        raise SystemExit(0)

    _with_sections(monkeypatch, [("sneaky", sneaky)])
    assert bench_run.main([]) == 1
    assert "sneaky/FAILED,0,SystemExit" in capsys.readouterr().out


def test_unmatched_filter_exits_nonzero(monkeypatch, capsys):
    _with_sections(monkeypatch, [("layout_policy", lambda tmp: None)])
    assert bench_run.main(["layout_polcy"]) == 2      # typo'd CI cell
    err = capsys.readouterr().err
    assert "matched no section" in err and "layout_policy" in err


def test_filter_substring_selects(monkeypatch):
    ran = []
    _with_sections(monkeypatch, [("fig4_write", lambda tmp: ran.append("w")),
                                 ("fig5_read", lambda tmp: ran.append("r"))])
    assert bench_run.main(["fig5"]) == 0
    assert ran == ["r"]


def test_keyboard_interrupt_propagates(monkeypatch):
    def interrupted(tmp):
        raise KeyboardInterrupt

    _with_sections(monkeypatch, [("slow", interrupted)])
    with pytest.raises(KeyboardInterrupt):
        bench_run.main([])
