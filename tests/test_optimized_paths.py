"""Optimized-variant equivalence: flash attention and local MoE dispatch
must match the baseline paths (f32-exact for flash; routing-exact for MoE),
and the flash kernel must sweep shapes/dtypes against the oracle."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.kernels.flash_attention import flash_attention
from repro.models import LM
from repro.models.attention import attn_defs, attn_forward
from repro.models.params import materialize


def _ref(q, k, v, causal, window, softcap, scale):
    B, H, Lq, D = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Lq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    m = jnp.ones((Lq, k.shape[2]), bool)
    if causal:
        m &= qp >= kp
    if window:
        m &= (qp - kp) < window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("causal,window,softcap",
                         [(True, None, None), (False, None, None),
                          (True, 48, None), (True, None, 30.0)])
@pytest.mark.parametrize("gqa", [(4, 4), (4, 2), (4, 1)])
def test_flash_kernel_sweep(causal, window, softcap, gqa):
    H, Hkv = gqa
    rng = np.random.default_rng(0)
    B, L, D = 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, L, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, L, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, L, D)) * 0.5, jnp.float32)
    scale = 1 / math.sqrt(D)
    out = flash_attention(q, k, v, scale, causal, window, softcap, 64, 64,
                          True)
    ref = _ref(q, k, v, causal, window, softcap, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_flash_kernel_grads():
    rng = np.random.default_rng(1)
    B, H, Hkv, L, D = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, L, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, L, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, L, D)) * 0.5, jnp.float32)
    scale = 1 / math.sqrt(D)

    def lf(q, k, v):
        return jnp.sum(flash_attention(q, k, v, scale, True, None, None,
                                       64, 64, True) ** 2)

    def lr(q, k, v):
        return jnp.sum(_ref(q, k, v, True, None, None, scale) ** 2)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_flash_model_path_matches_baseline_f32():
    p = materialize(attn_defs(64, 4, 2, 16, qkv_bias=True),
                    jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, 64)) * 0.5, jnp.float32)
    kw = dict(n_heads=4, n_kv=2, head_dim=16, causal=True)
    y0 = attn_forward(p, x, **kw)
    yf = attn_forward(p, x, flash=True, flash_block=16, **kw)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yf),
                               rtol=1e-4, atol=1e-4)


def test_flash_falls_back_on_indivisible_length():
    p = materialize(attn_defs(64, 4, 2, 16), jax.random.key(0))
    x = jnp.ones((1, 37, 64), jnp.float32) * 0.1
    y = attn_forward(p, x, n_heads=4, n_kv=2, head_dim=16, causal=True,
                     flash=True, flash_block=16)      # 37 % 16 != 0
    assert y.shape == (1, 37, 64)


def test_moe_local_dispatch_matches_gather():
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    cfg = get_smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    cfgl = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="local",
                                     capacity_factor=16.0))
    m0, ml = LM(cfg), LM(cfgl)
    params = m0.init(jax.random.key(2))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32)}
    with shd.use_sharding(mesh, shd.DEFAULT_RULES):
        l0, _ = jax.jit(m0.loss)(params, batch)
        ll, _ = jax.jit(ml.loss)(params, batch)
    assert abs(float(l0) - float(ll)) < 1e-3
