"""Multi-process kill matrix for distributed reorganization (ISSUE 6).

Real worker processes are SIGKILLed while parked at instrumented crash
points (``repro.distributed.reorg.BARRIERS``: mid-gather, pre-renew,
mid-write, pre-complete) and the tentpole guarantees are asserted at each
cell:

* after the kill the destination is *absent* (no ``index.json``; dead
  bytes and a journal at worst) and the source is byte-identical;
* a restarted fleet adopts the journal and converges to a destination
  bit-identical to a single-process ``reorganize`` of the same source;
* an elastic N -> N-1 shrink (one worker SIGKILLed mid-fleet) is detected
  by the coordinator's heartbeat monitor, the ``plan_rescale`` decision is
  journaled, and the survivors converge alone;
* a live reader polling the destination throughout never observes a torn
  layout — only "not there yet" or the complete, correct dataset.

Every wait here is bounded by an explicit deadline, so a wedged fleet
fails the test instead of hanging it.
"""

import hashlib
import json
import multiprocessing as mp
import os
import shutil
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import plan_layout, simulate_load_balance, uniform_grid_blocks
from repro.core.blocks import Block
from repro.distributed.reorg import (BARRIERS, distributed_reorganize,
                                     worker_main)
from repro.io import Dataset, build_write_plan, choose_reorg_layout, reorganize
from repro.io.journal import REORG_JOURNAL_NAME, ReorgJournal

GLOBAL = (32, 32, 32)
WAIT_S = 60.0


def _world(seed=7, nprocs=4):
    blocks = simulate_load_balance(uniform_grid_blocks(GLOBAL, (8, 8, 8)),
                                   num_procs=nprocs, seed=seed)
    rng = np.random.default_rng(seed)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    return blocks, data, ref


def _write_src(tmp_path, blocks, data):
    src = str(tmp_path / "src")
    ds = Dataset.create(src)
    ds.write("B", plan_layout("subfiled_fpp", blocks, num_procs=4,
                              global_shape=GLOBAL), np.float32, data)
    ds.close()
    return src


def _dir_hashes(d):
    out = {}
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name), "rb") as f:
            out[name] = hashlib.sha256(f.read()).hexdigest()
    return out


def _reference(tmp_path, src):
    """Single-process ``reorganize`` of a byte-identical copy of the source
    — the bit-identity oracle for the distributed fleet.  (A copy, because
    a successful reorganize records stats into its source directory.)"""
    src2 = str(tmp_path / "src_ref")
    shutil.copytree(src, src2)
    refdst = str(tmp_path / "dst_ref")
    _, ds, _ = reorganize(src2, refdst, "B", layout="auto", engine="pread")
    ds.close()
    return refdst


def _assert_bit_identical(d_a, d_b):
    bins_a = sorted(f for f in os.listdir(d_a) if f.endswith(".bin"))
    bins_b = sorted(f for f in os.listdir(d_b) if f.endswith(".bin"))
    assert bins_a == bins_b
    ha, hb = _dir_hashes(d_a), _dir_hashes(d_b)
    for f in bins_a:
        assert ha[f] == hb[f], f
    with open(os.path.join(d_a, "index.json")) as f:
        ja = json.load(f)
    with open(os.path.join(d_b, "index.json")) as f:
        jb = json.load(f)
    assert ja["chunks"] == jb["chunks"]      # extents, offsets AND crcs
    assert ja["variables"] == jb["variables"]


def _wait_for(predicate, timeout_s, what):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out after {timeout_s}s waiting for {what}")


def _arm_barrier(tmp_path, armed):
    """A barrier dir where only ``armed`` parks workers: every other crash
    point's release file pre-exists, so workers sail through them."""
    bdir = str(tmp_path / "barriers")
    os.makedirs(bdir, exist_ok=True)
    for name in BARRIERS:
        if name != armed:
            with open(os.path.join(bdir, f"go.{name}"), "w"):
                pass
    return bdir


def _reached(bdir, name):
    return [f for f in os.listdir(bdir) if f.endswith(f".{name}.reached")]


def _make_journal(src, dst, *, num_units, lease_timeout_s):
    """The coordinator's journal-creation path, inlined so the test owns
    the fleet (and can SIGKILL all of it) instead of the coordinator."""
    sds = Dataset.open(src, engine="pread", telemetry=False)
    decision = choose_reorg_layout(sds, "B")
    dtype = sds.index.var_dtype("B")
    sds.close()
    plan = build_write_plan(decision.layout, "B", dtype)
    ReorgJournal.create(dst, plan, src, num_units=num_units,
                        lease_timeout_s=lease_timeout_s,
                        attrs={"var": "B", "engine": "pread",
                               "policy": decision.to_json()})


def _spawn_workers(dst, names, bdir):
    ctx = mp.get_context("spawn")
    procs = {}
    for w in names:
        p = ctx.Process(target=worker_main, args=(dst, w, "pread"),
                        kwargs={"barrier_dir": bdir}, daemon=True)
        p.start()
        procs[w] = p
    return procs


# -- the matrix: whole-fleet SIGKILL at each crash point ---------------------

@pytest.mark.parametrize("barrier", BARRIERS)
def test_fleet_sigkill_then_restart_converges(tmp_path, barrier):
    blocks, data, ref = _world()
    src = _write_src(tmp_path, blocks, data)
    refdst = _reference(tmp_path, src)
    src_before = _dir_hashes(src)
    dst = str(tmp_path / "dst")
    bdir = _arm_barrier(tmp_path, barrier)
    _make_journal(src, dst, num_units=4, lease_timeout_s=1.0)

    procs = _spawn_workers(dst, ["k0", "k1"], bdir)
    try:
        _wait_for(lambda: _reached(bdir, barrier), WAIT_S,
                  f"a worker parked at {barrier}")
        for p in procs.values():           # whole-fleet death, no cleanup
            if p.is_alive():
                os.kill(p.pid, signal.SIGKILL)
        for p in procs.values():
            p.join(timeout=10.0)
    finally:
        for p in procs.values():
            if p.is_alive():
                p.kill()

    # crash invariant: destination absent (journal + dead bytes at worst),
    # source untouched
    assert not os.path.exists(os.path.join(dst, "index.json"))
    assert os.path.exists(os.path.join(dst, REORG_JOURNAL_NAME))
    assert _dir_hashes(src) == src_before

    # a fresh fleet adopts the journal, inherits the expired leases, and
    # converges bit-identically to the single-process oracle
    ds, stats = distributed_reorganize(src, dst, "B", num_workers=2,
                                       engine="pread", round_timeout_s=WAIT_S)
    try:
        arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    finally:
        ds.close()
    np.testing.assert_array_equal(arr, ref)
    _assert_bit_identical(refdst, dst)
    assert not os.path.exists(os.path.join(dst, REORG_JOURNAL_NAME))
    assert stats["validation_failures"] == 0


# -- codec leg (index v4): compressed source through the same machinery ------

def test_fleet_sigkill_mid_write_compressed_source(tmp_path):
    """Kill-matrix codec leg: the source's extents are zlib-compressed
    (index v4), so every journaled work unit's gather DECODES stored bytes
    while the CRC validation path still checksums them AS stored — the
    checksum definition over stored bytes is what keeps the journal and
    validation machinery codec-blind.  A mid-write fleet kill must leave
    the compressed source byte-identical, and a restarted fleet must
    converge bit-identically to the single-process oracle."""
    blocks, data, ref = _world(seed=31)
    src = str(tmp_path / "src")
    ds = Dataset.create(src)
    ds.write("B", plan_layout("subfiled_fpp", blocks, num_procs=4,
                              global_shape=GLOBAL), np.float32, data,
             codec="zlib")
    # CRCs are defined over STORED bytes: a compressed dataset validates
    # without decoding anything
    checked, bad = ds.verify_checksums("B")
    assert checked > 0 and bad == []
    ds.close()
    refdst = _reference(tmp_path, src)
    src_before = _dir_hashes(src)
    dst = str(tmp_path / "dst")
    bdir = _arm_barrier(tmp_path, "mid_write")
    _make_journal(src, dst, num_units=4, lease_timeout_s=1.0)

    procs = _spawn_workers(dst, ["k0", "k1"], bdir)
    try:
        _wait_for(lambda: _reached(bdir, "mid_write"), WAIT_S,
                  "a worker parked at mid_write")
        for p in procs.values():
            if p.is_alive():
                os.kill(p.pid, signal.SIGKILL)
        for p in procs.values():
            p.join(timeout=10.0)
    finally:
        for p in procs.values():
            if p.is_alive():
                p.kill()

    assert not os.path.exists(os.path.join(dst, "index.json"))
    assert _dir_hashes(src) == src_before      # compressed source untouched

    ds, stats = distributed_reorganize(src, dst, "B", num_workers=2,
                                       engine="pread", round_timeout_s=WAIT_S)
    try:
        arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    finally:
        ds.close()
    np.testing.assert_array_equal(arr, ref)
    _assert_bit_identical(refdst, dst)
    assert stats["validation_failures"] == 0


# -- elastic shrink: N -> N-1, survivors converge ----------------------------

def test_elastic_shrink_survivors_converge(tmp_path):
    blocks, data, ref = _world(seed=13)
    src = _write_src(tmp_path, blocks, data)
    dst = str(tmp_path / "dst")
    bdir = _arm_barrier(tmp_path, "mid_gather")
    journal = ReorgJournal(dst)
    result = {}

    def run():
        ds, stats = distributed_reorganize(
            src, dst, "B", num_workers=3, units_per_worker=2,
            engine="pread", lease_timeout_s=2.0, round_timeout_s=120.0,
            barrier_dir=bdir)
        try:
            result["arr"], _ = ds.read("B", Block((0, 0, 0), GLOBAL))
        finally:
            ds.close()
        result["stats"] = stats

    t = threading.Thread(target=run, daemon=True)
    t.start()
    try:
        _wait_for(lambda: _reached(bdir, "mid_gather"), WAIT_S,
                  "a worker parked at mid_gather")
        marker = sorted(_reached(bdir, "mid_gather"))[0]
        victim = marker.split(".")[0]
        with open(os.path.join(bdir, marker)) as f:
            os.kill(int(f.read()), signal.SIGKILL)

        def death_recorded():
            try:
                events = journal.load()["events"]
            except (OSError, ValueError):
                return False
            return any(e.get("event") == "worker_dead"
                       and e.get("worker") == victim for e in events)

        # the coordinator's heartbeat monitor must notice the silent worker
        # and journal the rescale decision while the fleet is still parked
        _wait_for(death_recorded, WAIT_S, "the worker's death to be journaled")
        with open(os.path.join(bdir, "go.mid_gather"), "w"):
            pass
    finally:
        t.join(timeout=120.0)
    assert not t.is_alive(), "elastic fleet did not converge"

    np.testing.assert_array_equal(result["arr"], ref)
    deaths = [e for e in result["stats"]["events"]
              if e["event"] == "worker_dead"]
    assert [d["worker"] for d in deaths] == [victim]
    assert "-> (2, 1)" in deaths[0]["rescale"]     # the N-1 mesh decision
    assert result["stats"]["rounds"] == 1          # survivors, same fleet
    assert not os.path.exists(os.path.join(dst, REORG_JOURNAL_NAME))


# -- live reader: old state or new state, never torn -------------------------

def test_live_reader_never_sees_torn_layout(tmp_path):
    blocks, data, ref = _world(seed=23)
    src = _write_src(tmp_path, blocks, data)
    dst = str(tmp_path / "dst")
    stop = threading.Event()
    problems, observations = [], []

    def reader():
        while not stop.is_set():
            try:
                ds = Dataset.open(dst, engine="pread", telemetry=False)
            except FileNotFoundError:
                observations.append("absent")
                time.sleep(0.002)
                continue
            try:
                arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
                if np.array_equal(arr, ref):
                    observations.append("consistent")
                else:
                    problems.append("read complete but wrong bytes")
            except Exception as exc:   # noqa: BLE001 — any tear is a failure
                problems.append(repr(exc))
            finally:
                ds.close()

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    try:
        ds, _ = distributed_reorganize(src, dst, "B", num_workers=2,
                                       engine="pread", round_timeout_s=WAIT_S)
        ds.close()
    finally:
        stop.set()
        t.join(timeout=30.0)

    assert problems == []
    assert "absent" in observations            # it saw the old state
    # and the committed state is the complete, correct dataset
    ds = Dataset.open(dst, engine="pread", telemetry=False)
    try:
        arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    finally:
        ds.close()
    np.testing.assert_array_equal(arr, ref)
