"""Expert re-placement planner + its layout-engine integration."""

import numpy as np
import jax.numpy as jnp

from repro.core.clustering import cluster_blocks
from repro.distributed.expert_placement import (apply_permutation,
                                                migration_blocks,
                                                plan_expert_placement)


def test_balances_skewed_loads():
    rng = np.random.default_rng(0)
    loads = rng.zipf(1.5, size=16).astype(float)
    plan = plan_expert_placement(loads, n_shards=4)
    assert sorted(plan.permutation) == list(range(16))
    assert plan.predicted_max_load <= plan.baseline_max_load
    # per-shard slot counts stay regular
    counts = np.bincount(plan.shard_of_expert, minlength=4)
    assert all(c == 4 for c in counts)


def test_uniform_loads_need_no_moves_quality():
    plan = plan_expert_placement([1.0] * 8, n_shards=2)
    assert plan.improvement == 1.0


def test_migration_blocks_feed_clustering():
    """Migrated expert shards form the paper's irregular block sets; the
    merge pass still produces valid fully-filled cuboids."""
    loads = [100, 1, 1, 1, 1, 1, 1, 100]
    plan = plan_expert_placement(loads, n_shards=2)
    blocks = migration_blocks(plan, weight_shape=(8, 64, 32))
    assert len(blocks) == 8
    for s in (0, 1):
        mine = [b for b in blocks if b.owner == s]
        cls = cluster_blocks(mine)
        assert sum(len(c.members) for c in cls) == len(mine)
        for c in cls:
            assert c.cuboid.volume == sum(m.volume for m in c.members)


def test_apply_permutation_roundtrip():
    w = jnp.arange(8 * 3).reshape(8, 3)
    plan = plan_expert_placement([5, 1, 1, 1, 1, 1, 1, 5], n_shards=2)
    w2 = apply_permutation(w, plan)
    # every expert row present exactly once
    assert sorted(np.asarray(w2[:, 0]).tolist()) == \
        sorted(np.asarray(w[:, 0]).tolist())
