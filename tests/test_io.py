"""Integration tests for the io substrate: every layout strategy must
round-trip bit-exactly under whole-domain, sub-region, decomposed and
pattern reads, through every execution engine; staging and post-hoc
reorganization must too.  The write path must stay byte-identical to the
seed writer (offset logic embedded below as the oracle), and a partially
executed WritePlan must leave ``index.json`` unwritten."""

import hashlib
import json
import os

import numpy as np
import pytest

from repro.core import (STRATEGIES, plan_layout, simulate_load_balance,
                        uniform_grid_blocks)
from repro.core.blocks import Block
from repro.core.read_patterns import PATTERNS, pattern_region
from repro.io import (Dataset, ENGINES, GPFS_BLOCK, OverlappedPreadEngine,
                      PreadEngine, StagingExecutor, assemble_chunk,
                      build_write_plan, gather_to_nodes, reorganize)
from repro.io.format import (ChunkRecord, DatasetIndex, align_up,
                             extent_checksum, subfile_name)

GLOBAL = (64, 64, 64)
BLOCK = (16, 16, 16)
NPROCS, PPN = 8, 4


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(0)
    blocks = simulate_load_balance(uniform_grid_blocks(GLOBAL, BLOCK),
                                   num_procs=NPROCS, seed=5)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    return blocks, data, ref


def _write(d, name, plan, data, dtype=np.float32, align=None,
           engine="pread"):
    ds = Dataset.create(d, engine=engine)
    ws = ds.write_planned(ds.plan_write(name, plan, dtype, align=align), data)
    ds.close()
    return ws


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_roundtrip_all_strategies(tmp_path, world, strategy):
    blocks, data, ref = world
    d = str(tmp_path / strategy)
    plan = plan_layout(strategy, blocks, num_procs=NPROCS,
                       procs_per_node=PPN, global_shape=GLOBAL,
                       num_stagers=2)
    if strategy == "merged_node":
        _, data, _ = gather_to_nodes(blocks, data, PPN)
    ws = _write(d, "B", plan, data)
    assert ws.bytes_written >= ref.nbytes     # >= because reorg may pad
    ds = Dataset.open(d)
    arr, st = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    assert st.chunks_touched == plan.num_chunks

    sub = Block((5, 10, 3), (50, 33, 61))
    arr, _ = ds.read("B", sub)
    np.testing.assert_array_equal(arr, ref[sub.slices()])


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_engine_roundtrip(tmp_path, world, engine):
    """Every engine must write and read every other engine's datasets."""
    blocks, data, ref = world
    d = str(tmp_path / f"eng_{engine}")
    plan = plan_layout("merged_process", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data, engine=engine)
    sub = Block((3, 0, 17), (64, 40, 60))
    for read_engine in sorted(ENGINES):
        ds = Dataset.open(d, engine=read_engine)
        arr, st = ds.read("B", Block((0, 0, 0), GLOBAL))
        np.testing.assert_array_equal(arr, ref)
        arr, _ = ds.read("B", sub)
        np.testing.assert_array_equal(arr, ref[sub.slices()])
        ds.close()


def test_engine_overlapped_depth_spec(tmp_path, world):
    """'overlapped:<depth>' engine spec and per-call engine override."""
    blocks, data, ref = world
    d = str(tmp_path / "depth")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    ds = Dataset.open(d, engine="overlapped:2")
    assert ds.engine == "overlapped"
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL), engine="memmap")
    np.testing.assert_array_equal(arr, ref)
    with pytest.raises(ValueError):
        Dataset.open(d, engine="io_uring")
    ds.close()


@pytest.mark.parametrize("pattern", PATTERNS)
def test_patterns_and_decompositions(tmp_path, world, pattern):
    blocks, data, ref = world
    d = str(tmp_path / "ds")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(d, "B", plan, data)
    ds = Dataset.open(d)
    region = pattern_region(pattern, GLOBAL)
    for scheme in [(1, 1, 1), (2, 1, 1), (1, 2, 2)]:
        st = ds.read_decomposed("B", region, scheme)
        assert st.bytes_read == region.volume * 4
    scheme, st = ds.read_pattern("B", pattern, num_readers=4)
    assert int(np.prod(scheme)) <= 4


def test_merged_layouts_reduce_chunks(world):
    blocks, _, _ = world
    chunked = plan_layout("chunked", blocks, num_procs=NPROCS)
    merged_p = plan_layout("merged_process", blocks, num_procs=NPROCS)
    merged_n = plan_layout("merged_node", blocks, num_procs=NPROCS,
                           procs_per_node=PPN)
    assert merged_p.num_chunks <= chunked.num_chunks
    assert merged_n.num_chunks <= merged_p.num_chunks


# -- write-plan structure ----------------------------------------------------

def test_write_plan_sorted_and_coalesced(world):
    blocks, _, _ = world
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    wp = build_write_plan(plan, "B", np.float32)
    # rows sorted by (subfile, offset), extents disjoint
    order = np.lexsort((wp.file_lo, wp.subfiles))
    assert (order == np.arange(wp.num_chunks)).all()
    same = wp.subfiles[1:] == wp.subfiles[:-1]
    assert (wp.file_lo[1:][same] >= wp.file_hi[:-1][same]).all()
    # unaligned single-subfile append has zero padding: one group spanning
    # exactly the payload
    assert wp.num_groups == 1
    assert wp.span_bytes == wp.bytes_total


def test_write_plan_alignment_folded_in(world):
    blocks, _, _ = world
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    align = 1 << 20
    wp = build_write_plan(plan, "B", np.float32, align=align)
    assert (wp.file_lo % align == 0).all()
    # every aligned extent starts its own group (16 KiB chunks << 1 MiB)
    assert wp.num_groups == wp.num_chunks
    # appending continues past the existing end, aligned up
    wp2 = build_write_plan(plan, "E", np.float32, align=align,
                           base_offsets=wp.file_sizes)
    assert int(wp2.file_lo.min()) == align_up(wp.file_sizes[0], align)


# -- byte-identity vs the seed writer ---------------------------------------

def _seed_write_variable(dirpath, name, dtype, plan, data, align=None,
                         index=None):
    """The pre-refactor writer's exact offset/append/ftruncate logic,
    kept verbatim as the byte-identity oracle."""
    os.makedirs(dirpath, exist_ok=True)
    dtype = np.dtype(dtype)
    buffers = [assemble_chunk(cp, data, dtype) for cp in plan.chunks]
    offsets = {}
    if index is not None:
        for rec in index.chunks:
            end = rec.offset + rec.nbytes
            if end > offsets.get(rec.subfile, 0):
                offsets[rec.subfile] = end
    placed = []
    for cp, buf in zip(plan.chunks, buffers):
        off = align_up(offsets.get(cp.subfile, 0), align)
        placed.append((cp, buf, cp.subfile, off))
        offsets[cp.subfile] = off + buf.nbytes
    fds = {}
    for sf, end in offsets.items():
        fd = os.open(os.path.join(dirpath, subfile_name(sf)),
                     os.O_RDWR | os.O_CREAT)
        os.ftruncate(fd, max(end, os.fstat(fd).st_size))
        fds[sf] = fd
    for cp, buf, sf, off in placed:
        os.pwrite(fds[sf], memoryview(buf.reshape(-1).view(np.uint8)), off)
    for fd in fds.values():
        os.close(fd)
    if index is None:
        index = DatasetIndex()
    index.add_variable(name, plan.global_shape, dtype, plan.strategy)
    for cp, buf, sf, off in placed:
        index.chunks.append(ChunkRecord(var=name, lo=cp.chunk.lo,
                                        hi=cp.chunk.hi, subfile=sf,
                                        offset=off, nbytes=buf.nbytes,
                                        checksum=extent_checksum(buf)))
    index.num_subfiles = max(index.num_subfiles, len(offsets))
    index.save(dirpath)
    return index


def _file_digest(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 22)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()


def _assert_datasets_bit_identical(d_a, d_b, compare_index=True):
    bins_a = sorted(f for f in os.listdir(d_a) if f.endswith(".bin"))
    bins_b = sorted(f for f in os.listdir(d_b) if f.endswith(".bin"))
    assert bins_a == bins_b
    for f in bins_a:
        pa, pb = os.path.join(d_a, f), os.path.join(d_b, f)
        assert os.path.getsize(pa) == os.path.getsize(pb), f
        assert _file_digest(pa) == _file_digest(pb), f
    if compare_index:
        with open(os.path.join(d_a, "index.json")) as f:
            ja = json.load(f)
        with open(os.path.join(d_b, "index.json")) as f:
            jb = json.load(f)
        assert ja == jb


@pytest.mark.parametrize("align", [None, GPFS_BLOCK],
                         ids=["unaligned", "gpfs16M"])
@pytest.mark.parametrize("strategy", ["chunked", "subfiled_fpp",
                                      "merged_process", "reorganized"])
@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_engine_write_matches_seed_writer(tmp_path, align, strategy, engine):
    """WritePlan + every engine produce datasets byte-identical to the seed
    writer — data subfiles AND index.json — for two appended variables."""
    rng = np.random.default_rng(3)
    gshape = (32, 32, 32)          # small world: 16 MiB alignment => ~100 MB
    blocks = simulate_load_balance(uniform_grid_blocks(gshape, (16, 16, 16)),
                                   num_procs=4, seed=3)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    data2 = {k: v * 2 for k, v in data.items()}
    plan = plan_layout(strategy, blocks, num_procs=4, procs_per_node=2,
                       global_shape=gshape, reorg_scheme=(2, 2, 2),
                       num_stagers=2)
    d_seed = str(tmp_path / "seed")
    idx = _seed_write_variable(d_seed, "B", np.float32, plan, data,
                               align=align)
    _seed_write_variable(d_seed, "E", np.float32, plan, data2, align=align,
                         index=idx)

    d_new = str(tmp_path / "new")
    ds = Dataset.create(d_new, engine=engine)
    ds.write("B", plan, np.float32, data, align=align)
    ds.write("E", plan, np.float32, data2, align=align)
    ds.close()
    _assert_datasets_bit_identical(d_seed, d_new)


# -- crash consistency -------------------------------------------------------

class _CrashAfterFirstGroup(PreadEngine):
    """Writes the first extent group, then dies mid-plan."""

    name = "crash-test"

    def write_plan(self, plan, buffers, store):
        self._write_group(plan, 0, buffers, store)
        raise OSError("injected crash after first group")


def test_partial_write_plan_leaves_index_unwritten(tmp_path, world):
    blocks, data, _ = world
    d = str(tmp_path / "crash")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    ds = Dataset.create(d, engine=_CrashAfterFirstGroup())
    wplan = ds.plan_write("B", plan, np.float32)
    assert wplan.num_groups > 1
    with pytest.raises(OSError, match="injected crash"):
        ds.write_planned(wplan, data)
    # data extents may exist (dead space), but the commit never happened:
    assert not os.path.exists(os.path.join(d, "index.json"))
    assert "B" not in ds.index.variables and not ds.index.chunks
    ds.close()
    # the next session sees no dataset at all
    with pytest.raises(FileNotFoundError):
        Dataset.open(d)


class _FlakyOverlapped(OverlappedPreadEngine):
    """Overlapped engine that kills one group submission on the first plan
    execution (the 'kill between group submissions' crash), then heals."""

    name = "flaky-overlapped"

    def __init__(self, depth=4):
        super().__init__(depth=depth)
        self.tripped = False

    def _write_group(self, plan, g, buffers, store):
        if g == 1 and not self.tripped:
            self.tripped = True
            raise OSError("injected crash between group submissions")
        super()._write_group(plan, g, buffers, store)


def test_overlapped_write_crash_consistency_and_retry(tmp_path, world):
    """A crash between overlapped group submissions must leave index.json
    absent; retrying the same plan makes the dataset reopenable and
    bit-correct (extents are idempotent: same offsets both attempts)."""
    blocks, data, ref = world
    d = str(tmp_path / "crash_overlap")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    ds = Dataset.create(d, engine=_FlakyOverlapped())
    wplan = ds.plan_write("B", plan, np.float32)
    assert wplan.num_groups > 1
    with pytest.raises(OSError, match="injected crash"):
        ds.write_planned(wplan, data)
    assert not os.path.exists(os.path.join(d, "index.json"))
    assert "B" not in ds.index.variables and not ds.index.chunks
    # retry the same (already reserved) plan: now all groups land
    ds.write_planned(wplan, data)
    ds.close()
    ds2 = Dataset.open(d)
    arr, _ = ds2.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    ds2.close()


# -- staging -----------------------------------------------------------------

def test_staging_executor_roundtrip(tmp_path, world):
    blocks, data, ref = world
    sd = str(tmp_path / "staged")
    plan = plan_layout("reorganized", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL, reorg_scheme=(2, 2, 2),
                       num_stagers=2)
    ex = StagingExecutor(sd, num_workers=2, queue_depth=2)
    for step in range(3):
        ex.submit(step, "B", np.float32, plan, data)
    results = ex.drain()
    ex.close()
    assert [r.step for r in results] == [0, 1, 2]
    assert all(r.num_chunks == 8 for r in results)
    ds = Dataset.open(sd)
    for step in range(3):
        arr, _ = ds.read(f"B@{step}", Block((0, 0, 0), GLOBAL))
        np.testing.assert_array_equal(arr, ref)


def test_staging_blocking_regime(tmp_path, world):
    """queue_depth=1 with slow writes must eventually stall the producer."""
    blocks, data, ref = world
    sd = str(tmp_path / "staged_slow")
    plan = plan_layout("reorganized", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL, reorg_scheme=(4, 4, 4))
    ex = StagingExecutor(sd, num_workers=1, queue_depth=1,
                         link_gbps=None)
    stalls = [ex.submit(step, "B", np.float32, plan, data)
              for step in range(6)]
    ex.drain()
    ex.close()
    assert len(stalls) == 6     # completed despite backpressure


def test_staging_worker_failure_is_retryable(tmp_path, world):
    """A staging write that dies between overlapped group submissions is
    reported in StageResult.error, leaves index.json uncommitted for that
    step, and the producer can re-submit the step successfully."""
    blocks, data, ref = world
    sd = str(tmp_path / "staged_flaky")
    plan = plan_layout("reorganized", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL, reorg_scheme=(2, 2, 2),
                       num_stagers=2)
    ex = StagingExecutor(sd, num_workers=1, queue_depth=2,
                         engine=_FlakyOverlapped())
    ex.submit(0, "B", np.float32, plan, data)
    ex.submit(0, "B", np.float32, plan, data)     # the retry
    ex.submit(1, "B", np.float32, plan, data)
    results = ex.drain()
    ex.close()
    failed = [r for r in results if r.error]
    ok = [r for r in results if not r.error]
    assert len(failed) == 1 and "injected crash" in failed[0].error
    assert sorted(r.step for r in ok) == [0, 1]
    ds = Dataset.open(sd)
    for step in (0, 1):
        arr, _ = ds.read(f"B@{step}", Block((0, 0, 0), GLOBAL))
        np.testing.assert_array_equal(arr, ref)
    ds.close()


@pytest.mark.parametrize("align", [None, GPFS_BLOCK],
                         ids=["unaligned", "gpfs16M"])
def test_staging_bit_identical_to_writer(tmp_path, align):
    """Regression for the historical off-by-alignment drift: staging appends
    (which used to re-implement align_up) must produce datasets bit-identical
    to writer appends for the same LayoutPlan sequence."""
    rng = np.random.default_rng(11)
    gshape = (32, 32, 32)
    blocks = simulate_load_balance(uniform_grid_blocks(gshape, (16, 16, 16)),
                                   num_procs=4, seed=7)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    plan = plan_layout("reorganized", blocks, num_procs=4,
                       global_shape=gshape, reorg_scheme=(2, 2, 2),
                       num_stagers=2)

    sd = str(tmp_path / "staged")
    # one worker => deterministic append order across steps
    ex = StagingExecutor(sd, num_workers=1, queue_depth=2, align=align)
    for step in range(2):
        ex.submit(step, "B", np.float32, plan, data)
    ex.drain()
    ex.close()

    wd = str(tmp_path / "written")
    ds = Dataset.create(wd, engine="pread")
    for step in range(2):
        ds.write(f"B@{step}", plan, np.float32, data, align=align)
    ds.close()
    _assert_datasets_bit_identical(sd, wd)


# -- post-hoc reorganization -------------------------------------------------

def test_posthoc_reorganize(tmp_path, world):
    blocks, data, ref = world
    src = str(tmp_path / "src")
    dst = str(tmp_path / "dst")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    _write(src, "B", plan, data)
    reorg = plan_layout("reorganized", blocks, num_procs=NPROCS,
                        global_shape=GLOBAL, reorg_scheme=(4, 4, 4))
    read_s, dst_ds, ws = reorganize(src, dst, "B", reorg)
    assert ws.num_extents == 64
    arr, st = dst_ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    assert st.chunks_touched == 64
    dst_ds.close()


def test_multiple_variables_one_dataset(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "multi")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    ds = Dataset.create(d)
    ds.write("B", plan, np.float32, data)
    data2 = {k: v * 2 for k, v in data.items()}
    ds.write("E", plan, np.float32, data2)
    ds.close()
    ds = Dataset.open(d)
    arr, _ = ds.read("E", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref * 2)
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)


# -- shim retirement ----------------------------------------------------------

def test_deprecated_shims_removed():
    """write_variable/rewrite_dataset were removed this release (ISSUE 3);
    repro.io must not resurrect them."""
    import repro.io as io_mod
    assert not hasattr(io_mod, "write_variable")
    assert not hasattr(io_mod, "rewrite_dataset")
    with pytest.raises(ImportError):
        from repro.io import write_variable   # noqa: F401
