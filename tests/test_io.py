"""Integration tests for the io substrate: every layout strategy must
round-trip bit-exactly under whole-domain, sub-region, decomposed and
pattern reads; staging and post-hoc reorganization must too."""

import os

import numpy as np
import pytest

from repro.core import (STRATEGIES, plan_layout, simulate_load_balance,
                        uniform_grid_blocks)
from repro.core.blocks import Block
from repro.core.read_patterns import PATTERNS, pattern_region
from repro.io import (Dataset, StagingExecutor, gather_to_nodes,
                      rewrite_dataset, write_variable)

GLOBAL = (64, 64, 64)
BLOCK = (16, 16, 16)
NPROCS, PPN = 8, 4


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(0)
    blocks = simulate_load_balance(uniform_grid_blocks(GLOBAL, BLOCK),
                                   num_procs=NPROCS, seed=5)
    data = {b.block_id: rng.standard_normal(b.shape).astype(np.float32)
            for b in blocks}
    ref = np.zeros(GLOBAL, np.float32)
    for b in blocks:
        ref[b.slices()] = data[b.block_id]
    return blocks, data, ref


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_roundtrip_all_strategies(tmp_path, world, strategy):
    blocks, data, ref = world
    d = str(tmp_path / strategy)
    plan = plan_layout(strategy, blocks, num_procs=NPROCS,
                       procs_per_node=PPN, global_shape=GLOBAL,
                       num_stagers=2)
    if strategy == "merged_node":
        _, data, _ = gather_to_nodes(blocks, data, PPN)
    _, ws = write_variable(d, "B", np.float32, plan, data)
    assert ws.bytes_written >= ref.nbytes     # >= because reorg may pad
    ds = Dataset(d)
    arr, st = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    assert st.chunks_touched == plan.num_chunks

    sub = Block((5, 10, 3), (50, 33, 61))
    arr, _ = ds.read("B", sub)
    np.testing.assert_array_equal(arr, ref[sub.slices()])


@pytest.mark.parametrize("pattern", PATTERNS)
def test_patterns_and_decompositions(tmp_path, world, pattern):
    blocks, data, ref = world
    d = str(tmp_path / "ds")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    write_variable(d, "B", np.float32, plan, data)
    ds = Dataset(d)
    region = pattern_region(pattern, GLOBAL)
    for scheme in [(1, 1, 1), (2, 1, 1), (1, 2, 2)]:
        st = ds.read_decomposed("B", region, scheme)
        assert st.bytes_read == region.volume * 4
    scheme, st = ds.read_pattern("B", pattern, num_readers=4)
    assert int(np.prod(scheme)) <= 4


def test_merged_layouts_reduce_chunks(world):
    blocks, _, _ = world
    chunked = plan_layout("chunked", blocks, num_procs=NPROCS)
    merged_p = plan_layout("merged_process", blocks, num_procs=NPROCS)
    merged_n = plan_layout("merged_node", blocks, num_procs=NPROCS,
                           procs_per_node=PPN)
    assert merged_p.num_chunks <= chunked.num_chunks
    assert merged_n.num_chunks <= merged_p.num_chunks


def test_staging_executor_roundtrip(tmp_path, world):
    blocks, data, ref = world
    sd = str(tmp_path / "staged")
    plan = plan_layout("reorganized", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL, reorg_scheme=(2, 2, 2),
                       num_stagers=2)
    ex = StagingExecutor(sd, num_workers=2, queue_depth=2)
    for step in range(3):
        ex.submit(step, "B", np.float32, plan, data)
    results = ex.drain()
    ex.close()
    assert [r.step for r in results] == [0, 1, 2]
    assert all(r.num_chunks == 8 for r in results)
    ds = Dataset(sd)
    for step in range(3):
        arr, _ = ds.read(f"B@{step}", Block((0, 0, 0), GLOBAL))
        np.testing.assert_array_equal(arr, ref)


def test_staging_blocking_regime(tmp_path, world):
    """queue_depth=1 with slow writes must eventually stall the producer."""
    blocks, data, ref = world
    sd = str(tmp_path / "staged_slow")
    plan = plan_layout("reorganized", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL, reorg_scheme=(4, 4, 4))
    ex = StagingExecutor(sd, num_workers=1, queue_depth=1,
                         link_gbps=None)
    stalls = [ex.submit(step, "B", np.float32, plan, data)
              for step in range(6)]
    ex.drain()
    ex.close()
    assert len(stalls) == 6     # completed despite backpressure


def test_posthoc_rewrite(tmp_path, world):
    blocks, data, ref = world
    src = str(tmp_path / "src")
    dst = str(tmp_path / "dst")
    plan = plan_layout("subfiled_fpp", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    write_variable(src, "B", np.float32, plan, data)
    reorg = plan_layout("reorganized", blocks, num_procs=NPROCS,
                        global_shape=GLOBAL, reorg_scheme=(4, 4, 4))
    read_s, idx, ws = rewrite_dataset(src, dst, "B", reorg)
    ds = Dataset(dst)
    arr, st = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
    assert st.chunks_touched == 64


def test_multiple_variables_one_dataset(tmp_path, world):
    blocks, data, ref = world
    d = str(tmp_path / "multi")
    plan = plan_layout("chunked", blocks, num_procs=NPROCS,
                       global_shape=GLOBAL)
    idx, _ = write_variable(d, "B", np.float32, plan, data)
    data2 = {k: v * 2 for k, v in data.items()}
    write_variable(d, "E", np.float32, plan, data2, index=idx)
    ds = Dataset(d)
    arr, _ = ds.read("E", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref * 2)
    arr, _ = ds.read("B", Block((0, 0, 0), GLOBAL))
    np.testing.assert_array_equal(arr, ref)
