"""The §5.2 model must reproduce the paper's worked examples exactly."""

import pytest

from repro.core.cost_model import (PAPER_TIMINGS, breakeven_outputs,
                                   is_blocking, onthefly_utilization,
                                   posthoc_utilization, recommend,
                                   tc_lower_bound_blocking,
                                   tc_upper_bound_nonblocking)


def test_table2_fixture():
    t = PAPER_TIMINGS
    assert (t.t_s, t.t_w_stage, t.t_w_sim, t.t_r_stage) == (19.4, 13.6, 1.4, 11.1)
    assert (t.n, t.m) == (256, 2)


def test_paper_example_tc40():
    """t_c=40: non-blocking; U_o = 258(40N+33); U_p = 10647.8N; N >= 26."""
    t = PAPER_TIMINGS
    assert not is_blocking(t, 40.0)
    assert onthefly_utilization(t, 40.0, 10) == pytest.approx(258 * (400 + 33))
    assert posthoc_utilization(t, 40.0, 10) == pytest.approx(10647.8 * 10)
    assert breakeven_outputs(t, 40.0) == 26
    assert onthefly_utilization(t, 40, 26) < posthoc_utilization(t, 40, 26)
    assert onthefly_utilization(t, 40, 25) >= posthoc_utilization(t, 40, 25)


def test_paper_example_tc20():
    """t_c=20: blocking; U_o = 258(20+33N) > U_p = 5527.8N always."""
    t = PAPER_TIMINGS
    assert is_blocking(t, 20.0)
    assert onthefly_utilization(t, 20.0, 7) == pytest.approx(258 * (20 + 33 * 7))
    assert posthoc_utilization(t, 20.0, 7) == pytest.approx(5527.8 * 7)
    assert breakeven_outputs(t, 20.0) is None


def test_paper_blocking_tc_window():
    """Paper: need 31.66 < t_c (< 33 to stay blocking) for eventual win."""
    t = PAPER_TIMINGS
    assert tc_lower_bound_blocking(t) == pytest.approx(8106.2 / 256, abs=1e-6)
    # just above the bound, a large-enough N wins
    assert breakeven_outputs(t, 32.0) is not None
    # just below, never
    assert breakeven_outputs(t, 31.0) is None


def test_paper_tc_upper_bound_N50():
    """Paper formula (407.8N - 8514) / (2N) at N=50 -> 118.76 s.

    (The paper's printed 150.26 is an arithmetic slip; we implement the
    paper's own symbolic bound.)"""
    t = PAPER_TIMINGS
    assert tc_upper_bound_nonblocking(t, 50) == pytest.approx(118.76)
    # asymptote: 407.8/2 = 203.9
    assert tc_upper_bound_nonblocking(t, 10 ** 9) == pytest.approx(203.9, abs=1e-3)
    # a t_c inside the bound wins at N=50, outside loses
    assert onthefly_utilization(t, 118.0, 50) < posthoc_utilization(t, 118.0, 50)
    assert onthefly_utilization(t, 120.0, 50) > posthoc_utilization(t, 120.0, 50)


def test_recommend_policy():
    t = PAPER_TIMINGS
    r = recommend(t, 40.0, 100)
    assert r["choose"] == "on_the_fly"
    r = recommend(t, 20.0, 100)
    assert r["choose"] == "post_hoc"


def test_breakeven_matches_bruteforce():
    """Property: the closed-form break-even equals brute-force scan."""
    t = PAPER_TIMINGS
    for t_c in (32.0, 35.0, 40.0, 60.0, 100.0):
        n = breakeven_outputs(t, t_c)
        brute = None
        for k in range(1, 200000):
            if onthefly_utilization(t, t_c, k) < posthoc_utilization(t, t_c, k):
                brute = k
                break
        assert n == brute, (t_c, n, brute)


# -- learned reorganization overhead (ISSUE 6 satellite) ---------------------

import json
import os

from repro.core.cost_model import (FALLBACK_CALIBRATION,
                                   REORG_CHUNK_OVERHEAD_S, REORG_STATS_ALPHA,
                                   REORG_STATS_NAME, load_reorg_overhead,
                                   load_reorg_stats, observe_reorg_overhead,
                                   predict_lifecycle_seconds)


def test_observe_reorg_overhead_first_observation(tmp_path):
    d = str(tmp_path)
    assert load_reorg_stats(d) is None
    assert load_reorg_overhead(d) is None
    st = observe_reorg_overhead(d, 2e-4, num_chunks=64)
    assert st.chunk_overhead_s == pytest.approx(2e-4)
    assert st.num_observations == 1
    assert load_reorg_overhead(d) == pytest.approx(2e-4)


def test_observe_reorg_overhead_ema(tmp_path):
    d = str(tmp_path)
    observe_reorg_overhead(d, 1e-4)
    st = observe_reorg_overhead(d, 2e-4)
    a = REORG_STATS_ALPHA
    assert st.chunk_overhead_s == pytest.approx(a * 2e-4 + (1 - a) * 1e-4)
    assert st.num_observations == 2


def test_reorg_stats_corrupt_or_invalid_degrade_to_none(tmp_path):
    d = str(tmp_path)
    p = os.path.join(d, REORG_STATS_NAME)
    with open(p, "w") as f:
        f.write("{not json")
    assert load_reorg_stats(d) is None
    with open(p, "w") as f:
        json.dump({"chunk_overhead_s": -1.0, "num_observations": 3,
                   "updated_at": 0.0, "version": 1}, f)
    assert load_reorg_stats(d) is None
    with open(p, "w") as f:
        json.dump({"chunk_overhead_s": 1e-4, "num_observations": 3,
                   "updated_at": 0.0, "version": 999}, f)
    assert load_reorg_stats(d) is None


def test_lifecycle_uses_learned_chunk_overhead():
    shape = {"groups": 4, "runs": 4, "bytes_moved": 1 << 20,
             "span_bytes": 1 << 20}
    base = predict_lifecycle_seconds(FALLBACK_CALIBRATION, write=shape,
                                     reads=0.0, num_chunks=100)
    learned = predict_lifecycle_seconds(FALLBACK_CALIBRATION, write=shape,
                                        reads=0.0, num_chunks=100,
                                        chunk_overhead_s=1e-2)
    # 100 chunks at 10 ms each must dominate the static default
    assert learned == pytest.approx(base
                                    + 100 * (1e-2 - REORG_CHUNK_OVERHEAD_S))
