"""Property-based oracle harness for the planner/policy core (ISSUE 5).

Seeded-random layouts × regions × ranks (1-D..4-D), asserting that the
policy's *analytic* plan-shape estimators reproduce the real planners
bit-for-bit:

* :func:`repro.core.policy.estimate_read_shape` (with extent placement)
  == :func:`repro.io.planner.build_read_plan` on runs, coalesced groups,
  payload bytes and span bytes — for every strategy, alignment and region
  the sweep generates;
* :func:`repro.core.policy.estimate_write_shape`
  == :func:`repro.io.planner.build_write_plan` on extent count, coalesced
  groups, payload and span.

No file I/O happens: the "dataset" is an in-memory ``DatasetIndex`` built
from the write plan's own extent table, which is exactly what the real
write path commits.

The second half asserts decision-level properties of the lifecycle policy:
permutation invariance in record order, recency/measured-cost weighting,
the expected-reads tradeoff, and cross-run prior round-trips.
"""

import json
import os
import time

import numpy as np
import pytest

from repro.core import uniform_grid_blocks
from repro.core.blocks import Block
from repro.core.layouts import plan_layout
from repro.core.policy import (ACCESS_PRIOR_NAME, AccessLog, AccessRecord,
                               LayoutPolicy, append_extent_offsets,
                               classify_region, estimate_read_shape,
                               estimate_write_shape, load_prior_records)
from repro.io.format import ChunkRecord, DatasetIndex
from repro.io.planner import build_read_plan, build_write_plan

NDIM_SHAPES = {1: (128,), 2: (64, 48), 3: (32, 32, 32), 4: (8, 8, 8, 8)}
NDIM_BLOCKS = {1: (16,), 2: (16, 12), 3: (8, 8, 8), 4: (4, 4, 4, 4)}


def _random_world(rng, ndim):
    """A random-ish but valid world for one rank: grid blocks plus a
    random layout strategy and alignment."""
    gshape = NDIM_SHAPES[ndim]
    blocks = uniform_grid_blocks(gshape, NDIM_BLOCKS[ndim])
    strategy = rng.choice(["reorganized", "subfiled_fpp", "chunked"])
    kwargs = {}
    if strategy == "reorganized":
        scheme = tuple(int(rng.choice([1, 2, 4])) for _ in range(ndim))
        kwargs = dict(reorg_scheme=scheme,
                      num_stagers=int(rng.integers(1, 4)))
    lay = plan_layout(strategy, blocks, num_procs=4, global_shape=gshape,
                      **kwargs)
    align = [None, 512, 4096][int(rng.integers(0, 3))]
    return gshape, lay, align


def _index_from_write_plan(wplan, gshape, strategy):
    """Commit a write plan's extent table into an in-memory index — the
    byte-for-byte metadata the real write path would persist."""
    idx = DatasetIndex()
    idx.add_variable("v", gshape, np.float32, strategy)
    for row in np.argsort(wplan.chunk_ids):
        idx.chunks.append(ChunkRecord(
            var="v", lo=tuple(int(x) for x in wplan.chunk_los[row]),
            hi=tuple(int(x) for x in wplan.chunk_his[row]),
            subfile=int(wplan.subfiles[row]),
            offset=int(wplan.file_lo[row]),
            nbytes=int(wplan.nbytes[row])))
    return idx


def _random_region(rng, gshape):
    lo = tuple(int(rng.integers(0, g)) for g in gshape)
    hi = tuple(int(rng.integers(l + 1, g + 1)) for l, g in zip(lo, gshape))
    return Block(lo, hi)


# -- write-shape oracle ------------------------------------------------------

@pytest.mark.parametrize("ndim", [1, 2, 3, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_estimate_write_shape_matches_write_plan(ndim, seed):
    rng = np.random.default_rng(1000 * ndim + seed)
    for _ in range(4):
        gshape, lay, align = _random_world(rng, ndim)
        wplan = build_write_plan(lay, "v", np.float32, align=align)
        los = np.asarray([c.chunk.lo for c in lay.chunks], dtype=np.int64)
        his = np.asarray([c.chunk.hi for c in lay.chunks], dtype=np.int64)
        subf = np.asarray([c.subfile for c in lay.chunks], dtype=np.int64)
        est = estimate_write_shape(los, his, 4, subfiles=subf, align=align)
        assert est.runs == wplan.num_chunks
        assert est.groups == wplan.num_groups
        assert est.bytes_needed == wplan.bytes_total
        assert est.span_bytes == wplan.span_bytes


@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_estimate_write_shape_matches_appends_to_existing(ndim):
    """Appending past existing extents (base_offsets) must also match."""
    rng = np.random.default_rng(77 + ndim)
    gshape, lay, align = _random_world(rng, ndim)
    base = {k: int(rng.integers(1, 100_000)) for k in range(4)}
    wplan = build_write_plan(lay, "v", np.float32, align=align,
                             base_offsets=base)
    los = np.asarray([c.chunk.lo for c in lay.chunks], dtype=np.int64)
    his = np.asarray([c.chunk.hi for c in lay.chunks], dtype=np.int64)
    subf = np.asarray([c.subfile for c in lay.chunks], dtype=np.int64)
    est = estimate_write_shape(los, his, 4, subfiles=subf, align=align,
                               base_offsets=base)
    assert (est.groups, est.runs, est.bytes_needed, est.span_bytes) == \
        (wplan.num_groups, wplan.num_chunks, wplan.bytes_total,
         wplan.span_bytes)
    # and the per-extent offsets themselves agree row-for-row
    nbytes = (his - los).prod(axis=1) * 4
    offs = append_extent_offsets(nbytes, subf, align=align,
                                 base_offsets=base)
    got = np.empty_like(offs)
    got[wplan.chunk_ids] = wplan.file_lo
    np.testing.assert_array_equal(offs, got)


def test_estimate_write_shape_default_subfiles_round_robin():
    """Without explicit subfiles the estimator assumes plan_layout's
    round-robin stager assignment."""
    gshape = (16, 16)
    blocks = uniform_grid_blocks(gshape, (4, 4))
    lay = plan_layout("reorganized", blocks, num_procs=1,
                      global_shape=gshape, reorg_scheme=(2, 2),
                      num_stagers=3)
    los = np.asarray([c.chunk.lo for c in lay.chunks], dtype=np.int64)
    his = np.asarray([c.chunk.hi for c in lay.chunks], dtype=np.int64)
    est = estimate_write_shape(los, his, 4, num_subfiles=3)
    wplan = build_write_plan(lay, "v", np.float32)
    assert est.groups == wplan.num_groups
    assert est.span_bytes == wplan.span_bytes


def test_estimate_write_shape_empty():
    z = np.empty((0, 3), dtype=np.int64)
    est = estimate_write_shape(z, z, 4)
    assert (est.groups, est.runs, est.bytes_needed, est.span_bytes) \
        == (0, 0, 0, 0)


# -- read-shape oracle -------------------------------------------------------

@pytest.mark.parametrize("ndim", [1, 2, 3, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_estimate_read_shape_matches_read_plan(ndim, seed):
    rng = np.random.default_rng(2000 * ndim + seed)
    for _ in range(3):
        gshape, lay, align = _random_world(rng, ndim)
        wplan = build_write_plan(lay, "v", np.float32, align=align)
        idx = _index_from_write_plan(wplan, gshape, lay.strategy)
        rows = idx.var_rows("v")
        for _ in range(8):
            region = _random_region(rng, gshape)
            rplan = build_read_plan(idx, "v", region)
            est = estimate_read_shape(rows.los, rows.his, region, 4,
                                      subfiles=rows.subfiles,
                                      offsets=rows.offsets)
            assert est.groups == rplan.num_groups, (gshape, region)
            assert est.runs == rplan.runs, (gshape, region)
            assert est.bytes_needed == rplan.bytes_needed
            assert est.span_bytes == rplan.span_bytes, (gshape, region)


@pytest.mark.parametrize("seed", [0, 1])
def test_estimate_read_shape_without_offsets_is_upper_bound(seed):
    """The placement-free estimate never under-counts groups or runs and
    always agrees on payload bytes."""
    rng = np.random.default_rng(31 + seed)
    gshape, lay, align = _random_world(rng, 3)
    wplan = build_write_plan(lay, "v", np.float32, align=align)
    idx = _index_from_write_plan(wplan, gshape, lay.strategy)
    rows = idx.var_rows("v")
    for _ in range(10):
        region = _random_region(rng, gshape)
        rplan = build_read_plan(idx, "v", region)
        est = estimate_read_shape(rows.los, rows.his, region, 4)
        assert est.groups >= rplan.num_groups
        assert est.runs >= rplan.runs
        assert est.bytes_needed == rplan.bytes_needed


def test_estimate_read_shape_miss_is_empty():
    t = uniform_grid_blocks((8, 8), (4, 4))
    los = np.asarray([b.lo for b in t])
    his = np.asarray([b.hi for b in t])
    est = estimate_read_shape(los, his, Block((100, 100), (101, 101)), 4,
                              subfiles=np.zeros(len(t), dtype=np.int64),
                              offsets=np.zeros(len(t), dtype=np.int64))
    assert (est.groups, est.runs, est.bytes_needed, est.span_bytes) \
        == (0, 0, 0, 0)


# -- batched pricing oracle --------------------------------------------------

@pytest.mark.parametrize("direction", ["read", "write"])
def test_predict_best_seconds_batch_matches_scalar(direction):
    """The vectorized best-engine pricing is the scalar model, exactly —
    per element, over random plan shapes including empty plans."""
    from repro.core.cost_model import (FALLBACK_CALIBRATION,
                                      predict_best_seconds,
                                      predict_best_seconds_batch)
    rng = np.random.default_rng(9)
    groups = rng.integers(0, 200, size=64)
    runs = groups + rng.integers(0, 5000, size=64)
    nbytes = rng.integers(0, 1 << 26, size=64)
    span = nbytes + rng.integers(0, 1 << 20, size=64)
    batch = predict_best_seconds_batch(
        FALLBACK_CALIBRATION, groups=groups, runs=runs, bytes_moved=nbytes,
        span_bytes=span, direction=direction)
    for i in range(64):
        scalar = predict_best_seconds(
            FALLBACK_CALIBRATION, groups=int(groups[i]), runs=int(runs[i]),
            bytes_moved=int(nbytes[i]), span_bytes=int(span[i]),
            direction=direction)
        assert batch[i] == pytest.approx(scalar, rel=1e-12, abs=1e-15)


@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_estimate_gather_shapes_matches_scalar_estimates(ndim):
    """The batched gather estimator agrees with one offset-free
    estimate_read_shape call per target region."""
    from repro.core.policy import estimate_gather_shapes
    rng = np.random.default_rng(40 + ndim)
    gshape = NDIM_SHAPES[ndim]
    src = uniform_grid_blocks(gshape, NDIM_BLOCKS[ndim])
    src_los = np.asarray([b.lo for b in src], dtype=np.int64)
    src_his = np.asarray([b.hi for b in src], dtype=np.int64)
    targets = [_random_region(rng, gshape) for _ in range(12)]
    tgt_los = np.asarray([t.lo for t in targets], dtype=np.int64)
    tgt_his = np.asarray([t.hi for t in targets], dtype=np.int64)
    gg, gr, gb, gs = estimate_gather_shapes(src_los, src_his,
                                            tgt_los, tgt_his, 4)
    for i, t in enumerate(targets):
        est = estimate_read_shape(src_los, src_his, t, 4)
        assert (gg[i], gr[i], gb[i], gs[i]) == \
            (est.groups, est.runs, est.bytes_needed, est.span_bytes)


# -- decision-level properties -----------------------------------------------

G3 = (32, 32, 32)


def _blocks3():
    return uniform_grid_blocks(G3, (8, 8, 8))


def _rec(region, shape=G3, var="B", seconds=1e-3, ts=None, source="live",
         kind="read"):
    return AccessRecord(var=var, kind=kind,
                        shape_class=classify_region(region, shape),
                        lo=region.lo, hi=region.hi, runs=64, groups=8,
                        nbytes=region.volume * 4, seconds=seconds,
                        ts=time.time() if ts is None else ts, source=source)


def _slab(shape=G3, thickness=4):
    return Block((0, 0, shape[2] // 2),
                 (shape[0], shape[1], shape[2] // 2 + thickness))


def _sub(shape=G3):
    return Block(tuple(g // 4 for g in shape),
                 tuple(g // 4 + g // 2 for g in shape))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_choose_layout_permutation_invariant(seed):
    """Shuffling the record order must not change the decision — or any
    score it was based on."""
    rng = np.random.default_rng(seed)
    now = time.time()
    regions = [_slab(), _sub(), _slab(thickness=2),
               Block((0, 0, 0), G3)]
    recs = [_rec(regions[int(rng.integers(0, len(regions)))],
                 seconds=float(rng.uniform(1e-5, 1e-2)),
                 ts=now - float(rng.uniform(0, 3600)))
            for _ in range(24)]
    blocks = _blocks3()
    base = LayoutPolicy(records=recs).choose_layout("B", blocks, G3,
                                                    now=now)
    for _ in range(3):
        perm = list(recs)
        rng.shuffle(perm)
        d = LayoutPolicy(records=perm).choose_layout("B", blocks, G3,
                                                     now=now)
        assert d.strategy == base.strategy
        assert d.scheme == base.scheme
        assert set(d.scores) == set(base.scores)
        for k in base.scores:
            assert d.scores[k] == pytest.approx(base.scores[k], rel=1e-9)


def test_recency_weighting_prefers_recent_pattern():
    """A stale slab history many half-lives old must lose to a handful of
    fresh sub-area reads; with equal timestamps the slab majority wins."""
    now = time.time()
    stale_slab = [_rec(_slab(), ts=now - 60 * 24 * 3600.0)
                  for _ in range(12)]
    fresh_sub = [_rec(_sub(), ts=now) for _ in range(3)]
    pol = LayoutPolicy(records=stale_slab + fresh_sub)
    mix = dict()
    for w, _r, cls in pol.pattern_mix(stale_slab + fresh_sub, now=now):
        mix[cls] = mix.get(cls, 0.0) + w
    assert mix["sub_area"] > 0.98
    # equal-age control: frequency wins again
    even = [_rec(_slab(), ts=now) for _ in range(12)] + \
        [_rec(_sub(), ts=now) for _ in range(3)]
    mix2 = dict()
    for w, _r, cls in pol.pattern_mix(even, now=now):
        mix2[cls] = mix2.get(cls, 0.0) + w
    assert mix2["slab(axis=2)"] > mix2["sub_area"]


def test_measured_cost_weighting_prefers_expensive_accesses():
    now = time.time()
    cheap_sub = [_rec(_sub(), seconds=1e-5, ts=now) for _ in range(8)]
    dear_slab = [_rec(_slab(), seconds=5e-2, ts=now) for _ in range(2)]
    pol = LayoutPolicy(records=cheap_sub + dear_slab)
    mix = dict()
    for w, _r, cls in pol.pattern_mix(cheap_sub + dear_slab, now=now):
        mix[cls] = mix.get(cls, 0.0) + w
    assert mix["slab(axis=2)"] > 0.9
    # untimed history degrades to pure frequency
    untimed = [_rec(_sub(), seconds=0.0, ts=now) for _ in range(8)] + \
        [_rec(_slab(), seconds=0.0, ts=now) for _ in range(2)]
    mix2 = dict()
    for w, _r, cls in pol.pattern_mix(untimed, now=now):
        mix2[cls] = mix2.get(cls, 0.0) + w
    assert mix2["sub_area"] == pytest.approx(0.8)


def test_expected_reads_trades_build_cost_against_read_cost():
    """The paper's central tension, in one assertion: with few expected
    reads the cheap-to-build candidate wins; with many, the read-optimal
    one does — and the read-optimal one has more chunks."""
    recs = [_rec(_slab()) for _ in range(4)]
    blocks = _blocks3()
    few = LayoutPolicy(records=recs).choose_layout(
        "B", blocks, G3, expected_reads=0.5)
    many = LayoutPolicy(records=recs).choose_layout(
        "B", blocks, G3, expected_reads=5000.0)
    assert few.scheme != many.scheme
    assert few.layout.num_chunks < many.layout.num_chunks
    # the many-reads decision matches read-only (v1) scoring
    v1 = LayoutPolicy(records=recs,
                      include_write_cost=False).choose_layout(
        "B", blocks, G3)
    assert many.scheme == v1.scheme
    assert v1.write_scores == {}


def test_effective_reads_is_decayed_record_mass():
    now = time.time()
    pol = LayoutPolicy()
    fresh = [_rec(_slab(), ts=now) for _ in range(6)]
    assert pol.effective_reads(fresh, now=now) == pytest.approx(6.0)
    stale = [_rec(_slab(), ts=now - 7 * 24 * 3600.0) for _ in range(6)]
    assert pol.effective_reads(stale, now=now) == pytest.approx(3.0)
    assert pol.effective_reads([], now=now) == 1.0   # floor


def test_decision_audit_fields_round_trip():
    recs = [_rec(_slab()) for _ in range(4)]
    d = LayoutPolicy(records=recs).choose_layout("B", _blocks3(), G3)
    j = json.loads(json.dumps(d.to_json()))
    assert j["expected_reads"] > 0
    assert set(j["read_scores"]) == set(j["scores"])
    assert set(j["write_scores"]) == set(j["scores"])
    best = min(j["scores"], key=lambda k: j["scores"][k])
    for k in j["scores"]:
        assert j["scores"][k] == pytest.approx(
            j["write_scores"][k] + j["expected_reads"] * j["read_scores"][k],
            rel=1e-6)
    assert "E[reads]" in j["reason"]


# -- cross-run priors --------------------------------------------------------

def test_prior_export_roundtrip(tmp_path):
    d = str(tmp_path)
    log = AccessLog(d)
    for _ in range(6):
        log.append(_rec(_slab()))
    path = log.export_prior()
    assert os.path.basename(path) == ACCESS_PRIOR_NAME
    prior = load_prior_records(path)
    assert len(prior) == 6
    assert all(r.source == "prior" for r in prior)
    # the seeded cold policy decides like the warm one
    warm = LayoutPolicy(log=log).choose_layout("B", _blocks3(), G3)
    cold = LayoutPolicy().with_prior(path).choose_layout("B", _blocks3(), G3)
    assert cold.scheme == warm.scheme
    assert cold.num_prior_records == 6
    assert "6 prior" in cold.reason


def test_prior_loads_from_directory_and_raw_log(tmp_path):
    d = str(tmp_path)
    log = AccessLog(d)
    for _ in range(4):
        log.append(_rec(_slab()))
    # directory without an exported prior falls back to access_log.json
    from_dir = load_prior_records(d)
    from_log = load_prior_records(log.path)
    assert len(from_dir) == len(from_log) == 4
    # an exported snapshot in the directory takes precedence
    log.export_prior()
    log.append(_rec(_sub()))
    assert len(load_prior_records(d)) == 4          # the snapshot
    assert len(load_prior_records(log.path)) == 5   # the live ring


def test_prior_survives_old_wall_clock_age(tmp_path):
    """A prior from a month-old run must still steer (live-ring TTL does
    not apply to priors — they are re-stamped at load)."""
    d = str(tmp_path)
    log = AccessLog(d)
    old = time.time() - 45 * 24 * 3600.0
    log._save([_rec(_slab(), ts=old) for _ in range(5)])
    assert log.records() == []                      # TTL kills the live view
    prior = load_prior_records(log.path)
    assert len(prior) == 5
    cold = LayoutPolicy().with_prior(log.path).choose_layout(
        "B", _blocks3(), G3)
    assert cold.num_records == 5
    # the month-old history decides exactly like an equivalent fresh one
    live = LayoutPolicy(
        records=[_rec(_slab()) for _ in range(5)]).choose_layout(
        "B", _blocks3(), G3)
    assert (cold.strategy, cold.scheme) == (live.strategy, live.scheme)


def test_prior_decays_as_live_telemetry_accumulates():
    now = time.time()
    prior = [_rec(_slab(), ts=now, source="prior") for _ in range(8)]
    live = [_rec(_sub(), ts=now) for _ in range(100)]
    pol = LayoutPolicy(records=live, prior_records=prior)
    mix = dict()
    for w, _r, cls in pol.pattern_mix(pol.records(), now=now):
        mix[cls] = mix.get(cls, 0.0) + w
    # 100 live records vs PRIOR_MASS=8: the prior's share is ~8/108
    assert mix["sub_area"] > 0.85
    # with no live telemetry the prior alone decides — exactly like the
    # same records would as live history
    alone = LayoutPolicy(prior_records=prior).choose_layout(
        "B", _blocks3(), G3)
    as_live = LayoutPolicy(
        records=[_rec(_slab(), ts=now) for _ in range(8)]).choose_layout(
        "B", _blocks3(), G3, now=now)
    assert (alone.strategy, alone.scheme) == (as_live.strategy,
                                              as_live.scheme)


def test_prior_missing_or_corrupt_degrades(tmp_path):
    pol = LayoutPolicy().with_prior(str(tmp_path / "nope.json"))
    assert pol.prior_records == []
    bad = tmp_path / "bad.json"
    bad.write_text("{broken")
    assert LayoutPolicy().with_prior(str(bad)).prior_records == []
    future = tmp_path / "future.json"
    future.write_text(json.dumps({"version": 999, "records": []}))
    assert LayoutPolicy().with_prior(str(future)).prior_records == []
    d = LayoutPolicy().with_prior(None).choose_layout("B", _blocks3(), G3)
    assert "no usable access history" in d.reason


def test_prior_record_json_round_trip():
    r = _rec(_slab(), source="prior")
    back = AccessRecord.from_json(json.loads(json.dumps(r.to_json())))
    assert back.source == "prior"
    live = _rec(_slab())
    j = live.to_json()
    assert "src" not in j                 # live files stay byte-compatible
    assert AccessRecord.from_json(j).source == "live"
