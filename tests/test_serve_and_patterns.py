"""Serving engine, read-pattern properties, and long-context decode caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.blocks import Block
from repro.core.read_patterns import (PATTERNS, best_decompositions,
                                      decompose_region, pattern_region)
from repro.models import LM
from repro.serve import ServeEngine, cache_bytes, cache_spec_summary


def test_serve_engine_greedy_deterministic():
    cfg = get_smoke_config("qwen2.5-3b")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, max_len=48)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 16))
    out1, _ = engine.generate(prompts, num_new=8)
    engine2 = ServeEngine(model, params, max_len=48)
    out2, _ = engine2.generate(prompts, num_new=8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)


def test_serve_engine_matches_stepwise_forward():
    """Greedy generation must equal repeated full-forward argmax."""
    cfg = get_smoke_config("yi-9b")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    engine = ServeEngine(model, params, max_len=32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (1, 8))
    out, _ = engine.generate(prompts, num_new=4)
    # reference: roll forward with full recompute
    from repro.models.layers import unembed_chunked
    toks = jnp.asarray(prompts, jnp.int32)
    ref = []
    for _ in range(4):
        h, _, _ = model.hidden(params, {"tokens": toks})
        nxt = jnp.argmax(unembed_chunked(
            h[:, -1:], params.get("lm_head", params.get("embed")),
            final_cap=cfg.final_cap), axis=-1).astype(jnp.int32)
        ref.append(int(nxt[0, 0]))
        toks = jnp.concatenate([toks, nxt], axis=1)
    assert out[0].tolist() == ref


def test_window_cache_is_ring_sized():
    """Sliding-window archs must allocate window-sized caches, and SSM archs
    constant-size state — the long_500k feasibility property."""
    cfg = get_smoke_config("gemma2-2b")      # window=8
    model = LM(cfg)
    summary = cache_spec_summary(model, batch=1, cache_len=1024)
    # pair_lg = window(8) local + full(1024) global
    full = cache_bytes(model, 1, 1024)
    half = cache_bytes(model, 1, 2048)
    # doubling context must NOT double cache (local layers stay at window)
    assert half < 2 * full
    cfg_ssm = get_smoke_config("mamba2-780m")
    m2 = LM(cfg_ssm)
    assert cache_bytes(m2, 1, 1024) == cache_bytes(m2, 1, 2 ** 16)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_pattern_regions_inside_domain(pattern):
    shape = (64, 48, 32)
    r = pattern_region(pattern, shape)
    assert all(0 <= lo < hi <= s for lo, hi, s in zip(r.lo, r.hi, shape))


def test_decompose_region_partitions():
    region = Block((4, 4, 4), (36, 20, 12))
    for scheme in [(2, 2, 2), (4, 1, 1), (1, 3, 2), (8, 8, 8)]:
        parts = decompose_region(region, scheme)
        assert sum(p.volume for p in parts) == region.volume
        for p in parts:
            assert region.contains(p)


def test_best_decompositions_cover_factorizations():
    ds = best_decompositions(8)
    assert (1, 1, 8) in ds and (2, 2, 2) in ds and (8, 1, 1) in ds
    assert all(a * b * c == 8 for a, b, c in ds)
