"""Codec registry + index v4 codec fields (ISSUE 10 tentpole).

The registry is the one shared seam between the format (per-record codec
name), the engines (decode in ``scatter_row``) and the cost model
(calibration v3 bandwidth terms) — these tests pin its contract: raw
bytes in, raw bytes out, lengths validated against the chunk record,
unknown names fail loudly, and the v4 record round-trips codec + logical
size through JSON without disturbing v1–v3 readers.
"""

import json

import numpy as np
import pytest

from repro.core.blocks import Block
from repro.core.codecs import (CODEC_NONE, CODECS, available_codecs,
                               codec_code, codec_name, decode, encode,
                               get_codec)
from repro.core.cost_model import probe_storage
from repro.io import Dataset
from repro.io.format import ChunkRecord
from repro.core import plan_layout, uniform_grid_blocks


def test_registry_baseline():
    """``none`` and ``zlib`` are always registered (stdlib only); codes
    are stable, ``none`` is code 0, and name <-> code round-trips."""
    names = available_codecs()
    assert names[0] == "none" and "zlib" in names
    assert codec_code("none") == CODEC_NONE == 0
    for n in names:
        assert codec_name(codec_code(n)) == n
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("zstd")
    with pytest.raises(ValueError, match="unknown codec code"):
        codec_name(99)


def test_encode_decode_roundtrip_buffer_protocol():
    """Codecs accept any buffer-protocol object (numpy views included)
    and round-trip exact bytes; decode accepts the name or the plan-array
    int code."""
    rng = np.random.default_rng(3)
    arr = rng.integers(0, 8, size=4096, dtype=np.uint8)
    for name in available_codecs():
        enc = encode(name, arr)
        assert decode(name, enc, arr.nbytes) == arr.tobytes()
        assert decode(codec_code(name), np.frombuffer(enc, np.uint8),
                      arr.nbytes) == arr.tobytes()
    # identity codec is a passthrough
    assert encode("none", arr) == arr.tobytes()


def test_decode_length_mismatch_fails_loudly():
    """A stored extent whose decoded size disagrees with the chunk record
    is torn or mislabeled — decode must raise, never return short bytes
    (same discipline as the CRC validation path)."""
    enc = encode("zlib", b"x" * 1024)
    with pytest.raises(ValueError, match="torn or mislabeled"):
        decode("zlib", enc, 1023)
    with pytest.raises(ValueError, match="torn or mislabeled"):
        decode("none", b"x" * 10, 11)


def test_chunk_record_v4_json_roundtrip():
    """v4 records carry codec + logical size; a raw record emits NEITHER
    key, so a raw v4 index is byte-compatible with what a v3 writer
    produces (modulo the version stamp)."""
    raw = ChunkRecord(var="v", lo=(0,), hi=(8,), subfile=0, offset=0,
                      nbytes=32)
    d = raw.to_json()
    assert "codec" not in d and "lbytes" not in d
    assert ChunkRecord.from_json(d).codec == "none"
    assert ChunkRecord.from_json(d).logical_nbytes == 32
    comp = ChunkRecord(var="v", lo=(0,), hi=(8,), subfile=0, offset=0,
                       nbytes=20, codec="zlib", lbytes=32)
    d = comp.to_json()
    assert d["codec"] == "zlib" and d["lbytes"] == 32
    back = ChunkRecord.from_json(json.loads(json.dumps(d)))
    assert back.codec == "zlib"
    assert back.nbytes == 20          # ALWAYS the stored on-disk size
    assert back.logical_nbytes == 32


def test_calibration_v3_measures_codec_bandwidth(tmp_path):
    """probe_storage measures compress/decompress bandwidth for every
    available codec and leaves the exclusion sentinel for absent ones."""
    cal = probe_storage(str(tmp_path), probe_bytes=1 << 20)
    assert cal.zlib_comp_bps > 0 and cal.zlib_decomp_bps > 0
    assert cal.codec_bps("zlib", "read") == cal.zlib_decomp_bps
    assert cal.codec_bps("zlib", "write") == cal.zlib_comp_bps
    assert cal.codec_bps("none") == float("inf")
    if "lz4" not in available_codecs():
        assert cal.codec_bps("lz4") < 0


def test_compressed_dataset_stores_fewer_bytes_and_reads_identical(tmp_path):
    """End-to-end v4 seam: compressible data written with codec="zlib"
    occupies fewer stored bytes than its logical size, records carry the
    codec, reads decode transparently (full region and partial
    intersections), and the CRC path validates stored bytes."""
    shape = (32, 48)
    blocks = uniform_grid_blocks(shape, (16, 16))
    arr = (np.arange(np.prod(shape), dtype=np.float32) % 5).reshape(shape)
    data = {b.block_id: np.ascontiguousarray(arr[b.slices()])
            for b in blocks}
    plan = plan_layout("chunked", blocks, num_procs=2, global_shape=shape)
    d = str(tmp_path / "ds")
    ds = Dataset.create(d, engine="pread")
    ds.write("T", plan, np.float32, data, codec="zlib")
    recs = [r for r in ds.index.chunks if r.var == "T"]
    assert all(r.codec == "zlib" for r in recs)
    assert all(r.lbytes is not None and r.nbytes < r.lbytes for r in recs)
    checked, bad = ds.verify_checksums("T")
    assert checked == len(recs) and bad == []
    got, _ = ds.read("T", Block((0, 0), shape))
    np.testing.assert_array_equal(got, arr)
    got, _ = ds.read("T", Block((3, 7), (29, 41)))
    np.testing.assert_array_equal(got, arr[3:29, 7:41])
    ds.close()


def test_write_planned_requires_encoded_buffers(tmp_path):
    """write_planned with a codec but no pre-encoded buffers is a
    contract violation (append offsets depend on encoded sizes), not a
    silent raw write."""
    shape = (8, 8)
    blocks = uniform_grid_blocks(shape, (8, 8))
    data = {b.block_id: np.zeros(b.shape, np.float32) for b in blocks}
    plan = plan_layout("chunked", blocks, num_procs=1, global_shape=shape)
    ds = Dataset.create(str(tmp_path / "ds"))
    wp = ds.plan_write("T", plan, np.float32)
    with pytest.raises(ValueError, match="encoded"):
        ds.write_planned(wp, data, codec="zlib")
    ds.close()
