"""Unit tests for the fault-tolerance primitives (ISSUE 6 satellite):
HeartbeatMonitor deadline logic under an injected clock, plan_rescale
mesh-shrink edges, and StragglerTracker outlier detection/reassignment."""

import pytest

from repro.distributed.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                               StragglerTracker, plan_rescale)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# -- HeartbeatMonitor --------------------------------------------------------

def test_heartbeat_all_alive_initially():
    clk = FakeClock()
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=10.0, clock=clk)
    assert mon.dead_hosts() == []
    assert mon.alive_hosts() == [0, 1, 2]


def test_heartbeat_declares_dead_after_deadline():
    clk = FakeClock()
    mon = HeartbeatMonitor([0, 1], timeout_s=10.0, clock=clk)
    clk.advance(5.0)
    mon.beat(1)
    clk.advance(6.0)           # host 0 last beat 11s ago, host 1 6s ago
    assert mon.dead_hosts() == [0]
    assert mon.alive_hosts() == [1]


def test_heartbeat_exactly_at_deadline_is_alive():
    # the deadline is strict: now - t must EXCEED the timeout
    clk = FakeClock()
    mon = HeartbeatMonitor([0], timeout_s=10.0, clock=clk)
    clk.advance(10.0)
    assert mon.dead_hosts() == []
    clk.advance(0.001)
    assert mon.dead_hosts() == [0]


def test_heartbeat_beat_revives_host():
    clk = FakeClock()
    mon = HeartbeatMonitor([0], timeout_s=1.0, clock=clk)
    clk.advance(5.0)
    assert mon.dead_hosts() == [0]
    mon.beat(0)
    assert mon.dead_hosts() == []


def test_heartbeat_beat_on_unknown_host_registers_it():
    # journal-seeded monitors start empty and learn workers from beats
    clk = FakeClock()
    mon = HeartbeatMonitor([], timeout_s=1.0, clock=clk)
    mon.beat(7)
    assert mon.alive_hosts() == [7]
    clk.advance(2.0)
    assert mon.dead_hosts() == [7]


# -- plan_rescale ------------------------------------------------------------

def test_plan_rescale_shrinks_data_axis():
    plan = plan_rescale((4, 2), 6, [0, 1, 2])
    assert isinstance(plan, ElasticPlan)
    assert plan.old_mesh == (4, 2)
    assert plan.new_mesh == (3, 2)          # model axis kept, dp = 6 // 2
    assert plan.surviving_hosts == [0, 1, 2]
    assert plan.batch_refactor == pytest.approx(4 / 3)
    assert "rescale (4, 2) -> (3, 2)" in plan.describe()


def test_plan_rescale_n_minus_one_workers():
    # the distributed-reorg elastic case: (N, 1) mesh, one worker dies
    plan = plan_rescale((3, 1), 2, ["w0", "w2"])
    assert plan.new_mesh == (2, 1)
    assert plan.batch_refactor == pytest.approx(1.5)


def test_plan_rescale_model_axis_unsatisfiable_raises():
    with pytest.raises(ValueError, match="not enough devices"):
        plan_rescale((4, 4), 3, [0])


def test_plan_rescale_model_axis_relaxed():
    # with model_axis_fixed=False the model axis may shrink instead
    plan = plan_rescale((4, 4), 3, [0], model_axis_fixed=False)
    assert plan.new_mesh == (1, 3)


def test_plan_rescale_no_loss_is_identity_mesh():
    plan = plan_rescale((2, 2), 4, [0, 1])
    assert plan.new_mesh == (2, 2)
    assert plan.batch_refactor == pytest.approx(1.0)


# -- StragglerTracker --------------------------------------------------------

def test_straggler_needs_two_samples():
    trk = StragglerTracker([0, 1, 2])
    assert trk.stragglers() == []
    trk.record(0, 1.0)
    assert trk.stragglers() == []           # a lone sample has no median


def test_straggler_detects_slow_host():
    trk = StragglerTracker([0, 1, 2], factor=1.5)
    for _ in range(5):
        trk.record(0, 1.0)
        trk.record(1, 1.1)
        trk.record(2, 5.0)
    assert trk.stragglers() == [2]


def test_straggler_ema_forgets_old_outliers():
    trk = StragglerTracker([0, 1], alpha=0.5, factor=1.5)
    trk.record(0, 1.0)
    trk.record(1, 10.0)                     # one bad step
    assert trk.stragglers() == [1]
    for _ in range(12):                     # then it runs at the median pace
        trk.record(0, 1.0)
        trk.record(1, 1.0)
    assert trk.stragglers() == []


def test_straggler_reassignment_moves_to_fastest():
    trk = StragglerTracker([0, 1, 2], factor=1.5)
    for _ in range(3):
        trk.record(0, 0.5)
        trk.record(1, 1.0)
        trk.record(2, 4.0)
    moves = trk.reassignment({0: 4, 1: 4, 2: 4})
    assert moves == {2: {"move_shards": 1, "to": 0}}


def test_straggler_reassignment_skips_empty_hosts():
    trk = StragglerTracker([0, 1], factor=1.5)
    for _ in range(3):
        trk.record(0, 1.0)
        trk.record(1, 4.0)
    assert trk.reassignment({0: 4, 1: 0}) == {}


def test_straggler_no_stragglers_no_moves():
    trk = StragglerTracker([0, 1])
    trk.record(0, 1.0)
    trk.record(1, 1.05)
    assert trk.reassignment({0: 1, 1: 1}) == {}
