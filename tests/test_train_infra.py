"""Trainer loop, optimizer, data pipeline, fault tolerance, collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.configs import get_smoke_config
from repro.data.pipeline import PipelineConfig, Prefetcher, SyntheticTokens
from repro.distributed.collectives import (compressed_psum_tree,
                                           dequantize_int8, quantize_int8)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerTracker, plan_rescale)
from repro.models import LM
from repro.train import OptimizerConfig, Trainer, warmup_cosine
from repro.train.optimizer import zero_moment_defs
from repro.models.params import ParamDef


def test_warmup_cosine_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, end_lr=1e-4, warmup_steps=10,
                          total_steps=100)
    lrs = [float(warmup_cosine(cfg, s)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)
    assert all(a >= b for a, b in zip(lrs[1:], lrs[2:]))   # decays


def test_trainer_loss_decreases():
    cfg = get_smoke_config("qwen2.5-3b")
    model = LM(cfg)
    pcfg = PipelineConfig(global_batch=8, seq_len=32, vocab=cfg.vocab,
                          seed=1)
    data = SyntheticTokens(pcfg)
    tr = Trainer(model, OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                                        total_steps=60), data)
    params, opt = tr.init(jax.random.key(0))
    params, opt, hist = tr.run(params, opt, num_steps=30, log_every=0)
    first = np.mean([m["loss"] for _, m in hist[:5]])
    last = np.mean([m["loss"] for _, m in hist[-5:]])
    assert last < first, (first, last)
    rep = tr.straggler_report()
    assert "median" in rep


def test_grad_accum_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch."""
    from repro.train import make_train_step, adamw_init
    cfg = get_smoke_config("yi-9b")
    model = LM(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    params = model.init(jax.random.key(0))
    ocfg = OptimizerConfig(warmup_steps=1, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(model, ocfg, grad_accum=1))(
        params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, ocfg, grad_accum=2))(
        params, adamw_init(params), batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_pipeline_determinism_and_restore():
    cfg = PipelineConfig(global_batch=4, seq_len=16, vocab=100, seed=7)
    a = SyntheticTokens(cfg)
    b1 = next(a)
    state = a.state()
    b2 = next(a)
    b = SyntheticTokens(cfg)
    b.restore(state)
    b2r = next(b)
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_host_sharding():
    full = PipelineConfig(global_batch=8, seq_len=16, vocab=100, seed=3)
    h0 = SyntheticTokens(PipelineConfig(global_batch=8, seq_len=16,
                                        vocab=100, seed=3, host_id=0,
                                        num_hosts=2))
    h1 = SyntheticTokens(PipelineConfig(global_batch=8, seq_len=16,
                                        vocab=100, seed=3, host_id=1,
                                        num_hosts=2))
    b0, b1 = next(h0), next(h1)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher():
    cfg = PipelineConfig(global_batch=2, seq_len=8, vocab=50, seed=0)
    pf = Prefetcher(SyntheticTokens(cfg), depth=2)
    batches = [next(pf) for _ in range(4)]
    ref = SyntheticTokens(cfg)
    for b in batches:
        np.testing.assert_array_equal(b["tokens"], next(ref)["tokens"])


def test_heartbeat_and_rescale():
    clock = [0.0]
    mon = HeartbeatMonitor(list(range(8)), timeout_s=10.0,
                           clock=lambda: clock[0])
    clock[0] = 5.0
    for h in range(6):
        mon.beat(h)
    clock[0] = 12.0
    assert set(mon.dead_hosts()) == {6, 7}
    plan = plan_rescale((16, 16), num_alive_devices=208,
                        surviving_hosts=mon.alive_hosts())
    assert plan.new_mesh == (13, 16)
    assert plan.batch_refactor == pytest.approx(16 / 13)


def test_straggler_tracker():
    st = StragglerTracker(range(4))
    for _ in range(5):
        for h in range(4):
            st.record(h, 1.0 if h != 2 else 3.0)
    assert st.stragglers() == [2]
    plan = st.reassignment({h: 4 for h in range(4)})
    assert 2 in plan and plan[2]["to"] != 2


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)) * 0.1, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.51 + 1e-9      # half-ULP of the quantizer


def test_compressed_psum_inside_shard_map():
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("pod",))
    grads = {"w": jnp.ones((8, 8), jnp.float32) * 0.3}

    def f(g):
        out, fb = compressed_psum_tree(g, "pod")
        return out, fb

    out, fb = shard_map(f, mesh=mesh,
                            in_specs=(jax.sharding.PartitionSpec(),),
                            out_specs=jax.sharding.PartitionSpec())(grads)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.3, rtol=0.02)


def test_zero_moment_defs_adds_data_axis():
    skel = {"w": ParamDef((128, 64), ("embed", "mlp"))}
    z = zero_moment_defs(skel)
    assert "zero_data" in z["w"].axes
    assert z["w"].dtype == "float32"
