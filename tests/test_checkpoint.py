"""Checkpoint round-trip, merged layouts, resharding, async staging."""

import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              RestoreStats, blocks_from_sharding,
                              flatten_pytree, unflatten_like)
from repro.core.blocks import Block, regular_decomposition, shard_grid_blocks
from repro.io import ReadStats


def _fake_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": rng.standard_normal((64, 32)).astype(np.float32),
        "segments": [{"attn": {"wq": rng.standard_normal(
            (4, 32, 16)).astype(np.float32)}}],
        "count": np.asarray(7, np.int32),
    }


def _block_map():
    # embed sharded 4x2 over 8 simulated hosts; wq sharded on dim1 over 4
    return {
        "embed": shard_grid_blocks((64, 32), (4, 2),
                                   lambda idx: idx[0] * 2 + idx[1]),
        "segments/0/attn/wq": shard_grid_blocks(
            (4, 32, 16), (1, 4, 1), lambda idx: idx[1]),
    }


@pytest.mark.parametrize("strategy", ["chunked", "subfiled_fpp",
                                      "merged_process", "reorganized"])
def test_save_restore_roundtrip(tmp_path, strategy):
    tree = _fake_tree()
    mgr = CheckpointManager(str(tmp_path / "ckpt"), strategy=strategy,
                            reorg_scheme=(2, 2) if strategy == "reorganized"
                            else None)
    stats = mgr.save(100, tree, block_map=_block_map())
    assert stats.bytes > 0
    restored, rstats = mgr.restore(100, template=tree)
    for a, b in zip(flatten_pytree(tree).values(),
                    flatten_pytree(restored).values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_merged_reduces_chunks(tmp_path):
    tree = _fake_tree()
    bm = {"embed": shard_grid_blocks((64, 32), (8, 1), lambda i: i[0] // 4)}
    raw = CheckpointManager(str(tmp_path / "a"), strategy="subfiled_fpp")
    s1 = raw.save(1, {"embed": tree["embed"]}, block_map=bm)
    merged = CheckpointManager(str(tmp_path / "b"),
                               strategy="merged_process")
    s2 = merged.save(1, {"embed": tree["embed"]}, block_map=bm)
    # 4 contiguous row-slabs per host merge into 1 cuboid per host
    assert s2.num_chunks < s1.num_chunks
    r, _ = merged.restore(1)
    np.testing.assert_array_equal(r["embed"], tree["embed"])


@pytest.mark.parametrize("engine", ["memmap", "pread", "overlapped"])
def test_restore_engine_matrix(tmp_path, engine):
    """Save/restore round-trips through every execution engine."""
    tree = _fake_tree()
    mgr = CheckpointManager(str(tmp_path / "ckpt"), engine=engine)
    mgr.save(3, tree, block_map=_block_map())
    restored, _ = mgr.restore(3, template=tree)
    for a, b in zip(flatten_pytree(tree).values(),
                    flatten_pytree(restored).values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_reports_per_variable_stats(tmp_path):
    """Restore returns RestoreStats: per-variable ReadStats with exactly one
    shared index probe per variable, aggregated on top."""
    tree = _fake_tree()
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    mgr.save(1, tree, block_map=_block_map())
    targets = {"embed": regular_decomposition((64, 32), (2, 1))}
    _, stats = mgr.restore(1, target_blocks=targets)
    assert isinstance(stats, RestoreStats)
    assert sorted(stats.per_var) == ["embed", "segments/0/attn/wq"]
    for name, vs in stats.per_var.items():
        assert isinstance(vs, ReadStats)
        assert vs.chunks_touched > 0
        assert vs.bytes_read > 0
    # both elastic shards of "embed" were served from the one shared probe
    assert stats.per_var["embed"].chunks_touched >= 2
    assert stats.bytes_read == sum(v.bytes_read
                                   for v in stats.per_var.values())


def test_elastic_reshard_restore(tmp_path):
    """Save on 8 'hosts', restore shards for a 2-host mesh."""
    tree = _fake_tree()
    mgr = CheckpointManager(str(tmp_path / "ckpt"),
                            strategy="merged_process")
    mgr.save(5, tree, block_map=_block_map())
    # new decomposition: 2 hosts, embed split along rows only
    targets = {"embed": regular_decomposition((64, 32), (2, 1))}
    flat, stats = mgr.restore(5, target_blocks=targets)
    shards = flat["embed"]
    full = np.concatenate([shards[0], shards[1]], axis=0)
    np.testing.assert_array_equal(full, tree["embed"])


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    t = {"x": np.ones((4, 4), np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]
    step, tree = mgr.restore_latest(template=t)
    assert step == 4


def test_scalars_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    t = {"w": np.ones((4, 4), np.float32), "count": np.asarray(42, np.int32)}
    mgr.save(1, t)
    r, _ = mgr.restore(1, template=t)
    assert int(r["count"]) == 42


def test_async_checkpointer(tmp_path):
    tree = {"w": np.random.default_rng(0).standard_normal(
        (64, 64)).astype(np.float32)}
    bm = {"w": shard_grid_blocks((64, 64), (4, 1), lambda i: i[0])}
    ac = AsyncCheckpointer(str(tmp_path / "async"), reorg_scheme=(2, 2),
                           num_workers=1, queue_depth=2, n_compute=256,
                           m_staging=2, t_w_direct=0.001)
    for step in range(3):
        ac.save(step, tree, block_map=bm)
    results = ac.finish()
    assert len(results) == 3
    timings = ac.timings(results)
    rec = ac.recommendation(t_c=10.0, N=100, timings=timings)
    assert rec.mode in ("on_the_fly", "post_hoc")
    # written data is readable
    from repro.io import Dataset
    ds = Dataset(str(tmp_path / "async"))
    arr, _ = ds.read("w@2", Block((0, 0), (64, 64)))
    np.testing.assert_array_equal(arr, tree["w"])


def test_blocks_from_sharding_single_device():
    """On the 1-CPU container a trivial sharding gives one block."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("x",))
    sh = NamedSharding(mesh, P())
    blocks = blocks_from_sharding((8, 4), sh, devices_per_host=4)
    assert len(blocks) == 1
    assert blocks[0].shape == (8, 4)


def test_flatten_unflatten_roundtrip():
    t = _fake_tree()
    flat = flatten_pytree(t)
    assert "segments/0/attn/wq" in flat
    back = unflatten_like(t, flat)
    for a, b in zip(flatten_pytree(back).values(), flat.values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- cross-run prior auto-discovery (ISSUE 6 satellite) ----------------------

def _run_with_history(root):
    """A run root with restore telemetry and an exported prior."""
    import os
    mgr = CheckpointManager(root)
    tree = _fake_tree()
    mgr.save(1, tree, block_map=_block_map())
    mgr.restore(1, template=tree)
    p = mgr.export_prior()
    assert os.path.exists(p)
    return p


def test_discover_prior_finds_newest_sibling(tmp_path):
    import os
    runs = tmp_path / "runs"
    p1 = _run_with_history(str(runs / "run_001"))
    p2 = _run_with_history(str(runs / "run_002"))
    os.utime(p1, (1_000_000, 1_000_000))    # run_002's prior is fresher
    m3 = CheckpointManager(str(runs / "run_003"))
    assert m3.discover_prior() == p2
    # discovery feeds layout_policy when no explicit prior was given
    assert m3.layout_policy() is not None


def test_discover_prior_excludes_own_root_and_handles_none(tmp_path):
    runs = tmp_path / "runs"
    m1 = CheckpointManager(str(runs / "run_001"))
    tree = _fake_tree()
    m1.save(1, tree, block_map=_block_map())
    m1.restore(1, template=tree)
    m1.export_prior()                       # only OUR root has a prior
    assert m1.discover_prior() is None      # own root is not a sibling
    lone = CheckpointManager(str(tmp_path / "elsewhere" / "run_x"))
    assert lone.discover_prior() is None    # cold start: no siblings at all


def test_explicit_prior_beats_discovery(tmp_path):
    runs = tmp_path / "runs"
    p1 = _run_with_history(str(runs / "run_001"))
    explicit = _run_with_history(str(tmp_path / "exported"))
    m = CheckpointManager(str(runs / "run_002"), prior=explicit)
    assert m.discover_prior() == p1         # a sibling exists...
    m.layout_policy()                       # ...but the explicit one is used
    assert m.prior == explicit
