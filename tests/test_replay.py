"""Trace replay (ISSUE 8): replaying one trace twice — under a pinned
clock, pinned calibration and frequency-only record weighting — must
produce identical read bytes, identical PolicyDecision audits and
identical final index chunk tables (one digest covers all three), under
every execution engine; a captured trace exported as a cross-run prior
must warm a cold dataset to the same layout decision live telemetry
produced; and the committed ``traces/`` corpus must replay clean."""

import os

import numpy as np
import pytest

from repro.core.blocks import Block, uniform_grid_blocks
from repro.core.cost_model import FALLBACK_CALIBRATION
from repro.core.layouts import plan_layout
from repro.core.policy import AccessLog, LayoutPolicy, load_prior_records
from repro.io import (Dataset, TraceRecorder, header_for_dataset,
                      load_trace, reorganize, replay_trace)

TRACES_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "traces")

SHAPE = (32, 32, 32)


def _capture(tmp_path, *, with_reorg=True) -> str:
    """A slab-skewed workload captured through the real hooks."""
    src = os.path.join(str(tmp_path), "capture_src")
    ds = Dataset.create(src, engine="memmap")
    blocks = [b.with_owner(i % 8) for i, b in
              enumerate(uniform_grid_blocks(SHAPE, (16, 16, 16)))]
    layout = plan_layout("subfiled_fpp", blocks, num_procs=8,
                         global_shape=SHAPE)
    arr = np.random.default_rng(41).standard_normal(SHAPE) \
        .astype(np.float32)
    ds.write("T", layout, np.float32,
             {cp.chunk.block_id: arr[cp.chunk.slices()]
              for cp in layout.chunks})
    path = os.path.join(str(tmp_path), "capture.jsonl")
    rec = TraceRecorder(path, header_for_dataset(ds, name="cap", seed=41,
                                                 attrs={"gate_var": "T"}))
    ds.attach_trace(rec)
    for _ in range(2):
        for z in range(0, 32, 4):           # the skew: thin z-slabs
            ds.read("T", Block((0, 0, z), (32, 32, z + 2)))
        ds.read("T", Block((8, 8, 8), (24, 24, 24)))
    ds.read_decomposed("T", Block((0, 0, 0), SHAPE), (2, 2, 1))
    ds.read_pattern("T", "plane_xy", num_readers=2, slab_thickness=4)
    if with_reorg:
        reorganize(src, src, "T", "auto", engine="memmap", trace=rec)
        ds.refresh()
        ds.read("T", Block((0, 0, 0), (32, 32, 4)))
    ds.detach_trace()
    ds.close()
    rec.close()
    return path


# ---------------------------------------------------------------------------
# satellite 3: determinism, per engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["memmap", "pread", "overlapped",
                                    "uring", "odirect"])
def test_replay_deterministic_per_engine(tmp_path, engine):
    # the kernel-bypass engines feature-detect and degrade to
    # overlapped/pread where unsupported, so these legs run everywhere:
    # on capable kernels they pin the real kernel path, elsewhere they
    # pin the documented fallback — deterministic either way
    trace = load_trace(_capture(tmp_path))
    r1 = replay_trace(trace, os.path.join(str(tmp_path), "rp1"),
                      engine=engine)
    r2 = replay_trace(trace, os.path.join(str(tmp_path), "rp2"),
                      engine=engine)
    assert r1.digest == r2.digest
    assert r1.decisions == r2.decisions and r1.decisions, \
        "the auto reorganize must leave an identical decision audit"
    assert r1.bytes_verified == r2.bytes_verified > 0
    assert r1.clock_end == r2.clock_end


def test_replay_rejects_auto_engine(tmp_path):
    trace = load_trace(_capture(tmp_path, with_reorg=False))
    with pytest.raises(ValueError, match="pinned engine"):
        replay_trace(trace, os.path.join(str(tmp_path), "rp"),
                     engine="auto")


def test_replay_catches_divergence(tmp_path):
    """The oracle check is live: an event whose region exceeds the
    materialized geometry cannot replay silently."""
    import dataclasses
    trace = load_trace(_capture(tmp_path, with_reorg=False))
    replay_trace(trace, os.path.join(str(tmp_path), "rp"))  # clean pass
    ev = next(e for e in trace.events if e.kind == "read")
    bad_ev = dataclasses.replace(ev, hi=tuple(h + 32 for h in ev.hi))
    bad = dataclasses.replace(trace, events=[bad_ev])
    with pytest.raises(Exception):
        replay_trace(bad, os.path.join(str(tmp_path), "rp_bad"))


# ---------------------------------------------------------------------------
# satellite 4: trace -> export_prior warms a cold dataset to the live
# decision
# ---------------------------------------------------------------------------

def test_trace_prior_matches_live_decision(tmp_path):
    path = _capture(tmp_path, with_reorg=False)
    trace = load_trace(path)
    src = os.path.join(str(tmp_path), "capture_src")
    # one pinned "now" for both sides — but it must postdate the capture's
    # wall-clock stamps (the live log's TTL drops records from the future)
    import time
    now = time.time() + 1.0

    ds = Dataset.open(src, telemetry=False)
    rows = ds.index.var_rows("T")
    blocks = [Block(tuple(int(v) for v in rows.los[i]),
                    tuple(int(v) for v in rows.his[i]),
                    owner=int(rows.subfiles[i]), block_id=i)
              for i in range(rows.n)]
    ds.close()

    live_log = AccessLog(src, clock=lambda: now)
    live = LayoutPolicy(log=live_log, calibration=FALLBACK_CALIBRATION) \
        .choose_layout("T", blocks, SHAPE, now=now)
    assert live.num_records > 0

    prior_path = trace.export_prior(
        os.path.join(str(tmp_path), "prior.json"), now=now)
    prior_records = load_prior_records(prior_path, now=now)
    assert len(prior_records) == sum(
        1 for e in trace.events
        if e.kind in ("read", "read_decomposed", "read_pattern", "serve"))
    cold = LayoutPolicy(prior_records=prior_records,
                        calibration=FALLBACK_CALIBRATION) \
        .choose_layout("T", blocks, SHAPE, now=now)
    assert cold.num_prior_records == len(prior_records)
    assert (cold.strategy, cold.scheme) == (live.strategy, live.scheme), \
        f"trace-warmed decision {cold.strategy}/{cold.scheme} diverges " \
        f"from live telemetry's {live.strategy}/{live.scheme}"
    # the control: an unwarmed policy has nothing to go on
    unwarmed = LayoutPolicy(calibration=FALLBACK_CALIBRATION) \
        .choose_layout("T", blocks, SHAPE, now=now)
    assert unwarmed.num_records == 0


# ---------------------------------------------------------------------------
# committed corpus
# ---------------------------------------------------------------------------

def test_committed_corpus_is_loadable():
    names = sorted(f for f in os.listdir(TRACES_DIR)
                   if f.endswith(".jsonl"))
    assert len(names) >= 7, f"corpus shrank: {names}"
    for f in names:
        tr = load_trace(os.path.join(TRACES_DIR, f))
        assert tr.events, f"{f} carries no events"


def test_committed_corpus_smoke_replay(tmp_path):
    """The cheapest committed scenario replays clean and deterministically
    — the in-tree guarantee that the corpus and the stack stay in sync
    (CI's replay job covers the rest of the roster)."""
    trace = load_trace(os.path.join(TRACES_DIR, "mixed_rw_small.jsonl"))
    r1 = replay_trace(trace, os.path.join(str(tmp_path), "a"))
    r2 = replay_trace(trace, os.path.join(str(tmp_path), "b"))
    assert r1.digest == r2.digest
    assert r1.bytes_verified > 0
    assert set(r1.counts) == {"read", "write", "stage_submit"}


def test_committed_corpus_scaled_replay(tmp_path):
    """The large PIC trace replays at half scale — the self-describing
    header travels through ``scaled()`` and still drives the full stack."""
    trace = load_trace(os.path.join(TRACES_DIR, "pic_slab_large.jsonl"))
    half = trace.scaled(2)
    r = replay_trace(half, os.path.join(str(tmp_path), "rp"))
    assert r.counts["reorganize"] == 1
    assert r.bytes_verified > 0
    full_shape = tuple(trace.header.variables["T"]["shape"])
    assert tuple(half.header.variables["T"]["shape"]) == \
        tuple(d // 2 for d in full_shape)
